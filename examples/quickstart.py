"""Quickstart: the paper in ~60 lines.

Reproduces PUMA's core result on the modeled 8 GB DDR system: standard
allocators can't feed a processing-using-DRAM substrate; PUMA's
subarray-aware worst-fit + hint-aligned allocation can.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    AddressMap,
    HugePageModel,
    MallocModel,
    PhysicalMemory,
    PumaAllocator,
    plan_rows,
    simulate_op,
)

AMAP = AddressMap()          # paper geometry: 8 GB, 1 MB subarrays
SIZE = 128_000 // 8          # a 128 Kb operand


def show(name, operands):
    plan = plan_rows("and", operands, AMAP)
    sim = simulate_op("and", operands, AMAP)
    print(
        f"  {name:14s} PUD-executable rows: {plan.pud_fraction:6.1%}   "
        f"simulated time: {sim.t_ns/1e3:8.1f} us   "
        f"(CPU-only would be {sim.t_cpu_ns/1e3:8.1f} us)"
    )


print("C[i] = A[i] AND B[i]  on the Ambit/RowClone substrate")
print(f"operand size: {SIZE} bytes;  DRAM: {AMAP.total_bytes//2**30} GiB, "
      f"{AMAP.region_bytes} B regions\n")

# 1) malloc: virtually contiguous, physically scattered -> 0 % in PUD
mem = PhysicalMemory(AMAP, seed=0)
malloc = MallocModel(mem)
show("malloc", [malloc.alloc(SIZE) for _ in range(3)])

# 2) huge pages: physically contiguous but subarray placement is luck
huge = HugePageModel(mem)
show("huge pages", [huge.alloc(SIZE) for _ in range(3)])

# 3) PUMA: pre-allocate a pool, worst-fit the first operand, align the rest
puma = PumaAllocator(mem)
puma.pim_preallocate(64)                  # pim_preallocate: 64 huge pages
A = puma.pim_alloc(SIZE)                  # pim_alloc: worst-fit
B = puma.pim_alloc_align(SIZE, A)         # pim_alloc_align: same subarrays
C = puma.pim_alloc_align(SIZE, A)
show("PUMA", [A, B, C])

print("\nPUMA stats:", puma.stats)
