"""Bulk bitwise pipeline on the PUD substrate kernels: build a bitmap-index
query (the paper's motivating workload family) from pud_bulk ops.

Query: count elements where (age in [32,64)) AND (active) OR (vip)
over packed bitplane columns — executed with Ambit-style AND/OR/NOT kernels
validated against jnp, plus a RowClone block copy for materialization.

    PYTHONPATH=src python examples/pud_bitwise.py
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels.pud_bulk import ops

N = 1 << 16                     # elements
rng = np.random.default_rng(0)

age = rng.integers(0, 100, N)
active = rng.integers(0, 2, N).astype(bool)
vip = rng.integers(0, 2, N).astype(bool)


def pack(bits: np.ndarray) -> jnp.ndarray:
    return jnp.asarray(np.packbits(bits).view(np.uint8).astype(np.uint8))


b_age_lo = pack(age >= 32)
b_age_hi = pack(age < 64)
b_active = pack(active)
b_vip = pack(vip)

# (age_lo AND age_hi AND active) OR vip — three PUD instructions
t0 = ops.pud_and(b_age_lo, b_age_hi)
t1 = ops.pud_and(t0, b_active)
res = ops.pud_or(t1, b_vip)

got = np.unpackbits(np.asarray(res))[:N].astype(bool)
want = ((age >= 32) & (age < 64) & active) | vip
assert (got == want).all(), "PUD bitmap query mismatch"
print(f"bitmap query over {N} rows: {got.sum()} matches — PUD ops == numpy")

# RowClone the result into a fresh pool block (materialized view)
pool = jnp.zeros((4, res.size), res.dtype).at[0].set(res)
pool = ops.pool_block_copy(pool, jnp.asarray([0]), jnp.asarray([3]))
assert (np.asarray(pool[3]) == np.asarray(res)).all()
print("RowClone block copy: materialized view verified")
