"""End-to-end serving driver: continuous batching over the PUMA paged KV
pool, comparing placement policies — the TPU adaptation of the paper's
experiment (block-table contiguity is the '% executable in PUD' analogue).

    PYTHONPATH=src python examples/serve_paged.py [--policy puma|first_fit|random]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.kv_pool import KVPoolConfig
from repro.models.transformer import LM
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default=None, help="run one policy (default: all)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config("stablelm_1_6b").smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(0, cfg.vocab_size, int(rng.integers(8, 48))))
        for _ in range(args.requests)
    ]

    policies = [args.policy] if args.policy else ["puma", "first_fit", "random"]
    for policy in policies:
        pool_cfg = KVPoolConfig(
            num_blocks=256, block_size=8, kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, n_layers=cfg.n_layers, max_seqs=6,
            max_blocks_per_seq=16, blocks_per_arena=32,
            policy=policy, dtype="float32",
        )
        eng = ServeEngine(model, params, pool_cfg, use_kernel=False)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=args.max_new))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        m = eng.metrics()
        print(
            f"{policy:10s} served {len(done):3d} reqs, "
            f"{int(m['tokens'])} tokens in {dt:5.1f}s | "
            f"contiguity={m['mean_contiguous_fraction']:.3f} "
            f"descriptors/tile={m['descriptors_per_tile']:.3f} "
            f"align_hits={int(m['align_hits'])} misses={int(m['align_misses'])}"
        )


if __name__ == "__main__":
    main()
