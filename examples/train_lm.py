"""End-to-end training driver: synthetic corpus -> packed batches -> AdamW
with checkpoint/restart and failure recovery.

Default is a fast CPU-sized model; ``--model 100m`` trains a ~100M-param
config (a few hundred steps is hours on CPU — it exists to demonstrate the
driver is real, and is the config you'd launch on a pod).

    PYTHONPATH=src python examples/train_lm.py --steps 100
    PYTHONPATH=src python examples/train_lm.py --steps 100 --inject-failure 37
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig
from repro.models.transformer import LM
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def model_for(size: str) -> ModelConfig:
    if size == "smoke":
        return get_config("stablelm_1_6b").smoke()
    if size == "100m":
        return dataclasses.replace(
            get_config("stablelm_1_6b"),
            name="stablelm-100m",
            n_layers=10, d_model=640, n_heads=10, n_kv_heads=10,
            head_dim=64, d_ff=1792, vocab_size=32768, dtype="float32",
        )
    raise SystemExit(f"unknown --model {size}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="raise at this step once, to demo recovery")
    args = ap.parse_args()

    cfg = model_for(args.model)
    model = LM(cfg, attn_impl="chunked", remat=None if args.model == "smoke" else "full")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_per_shard=args.batch)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, log_every=10, accum_steps=args.accum,
        grad_compression=args.grad_compression,
    )

    boom = {"armed": args.inject_failure is not None}

    def failure_hook(step):
        if boom["armed"] and step == args.inject_failure:
            boom["armed"] = False
            raise RuntimeError("injected failure (node loss simulation)")

    out = Trainer(
        model, data, ocfg, tcfg,
        failure_hook=failure_hook if args.inject_failure is not None else None,
    ).run()
    losses = [m["loss"] for _, m in out["history"]]
    print(
        f"\ndone: {len(losses)} steps, loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
        f"recoveries={out['recoveries']}, stragglers={out['stragglers']}"
    )


if __name__ == "__main__":
    main()
