#!/usr/bin/env bash
# Lightweight CI: tier-1 test suite + the persisted microbenchmarks in
# smoke mode (BENCH_translate.json and BENCH_channels.json for the perf
# trajectory), each gated on its speedup floors.
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== translate microbenchmark (smoke) =="
PYTHONPATH="src:." python benchmarks/translate_bench.py --smoke

echo "== BENCH_translate.json =="
python - <<'EOF'
import json
rec = json.load(open("BENCH_translate.json"))
fails = []
for name, want in [("decode/bank_region", 20), ("decode/cacheline", 20),
                   ("plan/malloc_512k_3op", 10), ("execute/malloc_512k_3op", 10)]:
    got = rec[name]["speedup"]
    status = "ok" if got >= want else "FAIL"
    if got < want:
        fails.append(name)
    print(f"  {status}: {name} {got:.1f}x (need >= {want}x)")
raise SystemExit(1 if fails else 0)
EOF

echo "== channel scaling (smoke) =="
PYTHONPATH="src:." python benchmarks/channel_bench.py --smoke

echo "== BENCH_channels.json =="
python - <<'EOF'
import json
rec = json.load(open("BENCH_channels.json"))
fails = []
# PUD throughput on striped 8-channel operands must scale >= 4x over 1 ch.
for name, want in [("scaling/256k/ch8", 4.0), ("contention/ch8", 4.0)]:
    got = rec[name]["speedup"]
    status = "ok" if got >= want else "FAIL"
    if got < want:
        fails.append(name)
    print(f"  {status}: {name} {got:.2f}x (need >= {want}x)")
raise SystemExit(1 if fails else 0)
EOF
echo "CI OK"
