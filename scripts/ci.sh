#!/usr/bin/env bash
# Lightweight CI: tier-1 test suite + the persisted microbenchmarks in
# smoke mode (BENCH_translate.json and BENCH_channels.json for the perf
# trajectory), each gated on its speedup floors, plus the fixed-seed
# chaos gate (fault-injection suite + BENCH_faults.json assertions), the
# fixed-seed churn gate (long-horizon aging suite + compaction recovery /
# journal-replay assertions on BENCH_churn.json), and the fixed-seed
# serve gate (load-harness suite + scenario-shape assertions on
# BENCH_serve.json, with a byte-identical rerun check), and the fixed-seed
# trace gate (recorder/replay/golden suite + GEMV-offload assertions on
# BENCH_trace.json).
#
#   bash scripts/ci.sh          # smoke lanes (default)
#   bash scripts/ci.sh --full   # + full-size lane: -m slow tests and the
#                               # ~1800-request serve_bench trajectory
set -euo pipefail
cd "$(dirname "$0")/.."

FULL=0
[[ "${1:-}" == "--full" ]] && FULL=1

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== translate microbenchmark (smoke) =="
PYTHONPATH="src:." python benchmarks/translate_bench.py --smoke

echo "== BENCH_translate.json =="
python - <<'EOF'
import json
rec = json.load(open("BENCH_translate.json"))
fails = []
for name, want in [("decode/bank_region", 20), ("decode/cacheline", 20),
                   ("plan/malloc_512k_3op", 10), ("execute/malloc_512k_3op", 10)]:
    got = rec[name]["speedup"]
    status = "ok" if got >= want else "FAIL"
    if got < want:
        fails.append(name)
    print(f"  {status}: {name} {got:.1f}x (need >= {want}x)")
raise SystemExit(1 if fails else 0)
EOF

echo "== channel scaling (smoke) =="
PYTHONPATH="src:." python benchmarks/channel_bench.py --smoke

echo "== BENCH_channels.json =="
python - <<'EOF'
import json
rec = json.load(open("BENCH_channels.json"))
fails = []
# PUD throughput on striped 8-channel operands must scale >= 4x over 1 ch.
for name, want in [("scaling/256k/ch8", 4.0), ("contention/ch8", 4.0)]:
    got = rec[name]["speedup"]
    status = "ok" if got >= want else "FAIL"
    if got < want:
        fails.append(name)
    print(f"  {status}: {name} {got:.2f}x (need >= {want}x)")
raise SystemExit(1 if fails else 0)
EOF

echo "== chaos suite (fixed-seed fault gate) =="
python -m pytest -m chaos -q

echo "== chaos benchmark (smoke) =="
PYTHONPATH="src:." python benchmarks/chaos_bench.py --smoke

echo "== BENCH_faults.json =="
python - <<'EOF'
import json
rec = json.load(open("BENCH_faults.json"))
fails = []
def gate(name, cond, detail):
    print(f"  {'ok' if cond else 'FAIL'}: {name} ({detail})")
    if not cond:
        fails.append(name)

f, s = rec["alloc/faulty"], rec["serve/faulty"]
# the fixed seed must reproduce the faulty section bit-for-bit
gate("determinism", rec["determinism"]["identical"] is True, "replay identical")
# the fallback chain absorbs every fault: nothing is silently dropped
gate("alloc absorbed", f["injected"]["alloc_misses"] > 0 and f["retries"] > 0,
     f"{f['injected']['alloc_misses']} misses, {f['retries']} retries")
gate("alloc degraded", 0.0 < f["fallback_fraction"] < 1.0,
     f"fallback_fraction={f['fallback_fraction']:.3f}")
gate("quarantine", f["quarantined_regions"] > 0,
     f"{f['quarantined_regions']} regions quarantined")
# RowClone faults fire at the documented 1e-3 rate and are priced, not free
for op in ("copy", "and"):
    p = rec[f"pud/{op}/degraded"]
    gate(f"pud {op} faults", p["faulted_rows"] > 0 and p["speedup"] < 1.0,
         f"{p['faulted_rows']} faulted rows, degradation {p['speedup']:.3f}x")
# serving ledger: done + rejected + cancelled == submitted (zero drops)
gate("serve ledger", s["done"] + s["rejected"] + s["cancelled"]
     == s["submitted"], f"{s['done']}/{s['submitted']} done")
gate("serve recovery", s["done"] > 0 and s["injected_misses"] > 0,
     f"{s['injected_misses']} injected misses, {s['preemptions']} preemptions")
raise SystemExit(1 if fails else 0)
EOF

echo "== churn suite (fixed-seed aging gate) =="
python -m pytest -m churn -q

echo "== churn benchmark (smoke) =="
PYTHONPATH="src:." python benchmarks/churn_bench.py --smoke --gate

echo "== BENCH_churn.json =="
python - <<'EOG'
import json
rec = json.load(open("BENCH_churn.json"))
fails = []
def gate(name, cond, detail):
    print(f"  {'ok' if cond else 'FAIL'}: {name} ({detail})")
    if not cond:
        fails.append(name)

p, c = rec["alloc/puma"], rec["alloc/puma_compact"]
# churn must actually erode the PUD-executable fraction...
gate("puma decay", p["frac_end"] < p["frac_start"] - 0.05,
     f"{p['frac_start']:.3f} -> {p['frac_end']:.3f} over {p['n']} cycles")
# ...and watermark compaction must win back >= half of what was lost
gate("compaction recovery", c["recovery"] >= 0.5,
     f"recovery={c['recovery']:.2%}, {len(c['compactions'])} passes")
gate("migration bit-exact", c["bit_exact"] is True, "live data intact")
j = rec["journal/crash_replay"]
gate("crash replay", j["identical"] is True
     and j["crash_replay_deterministic"] is True,
     f"{j['kept_events']}/{j['n']} events survive the crash cut")
s = rec["pool/serving_trace"]
gate("serving trace", s["bit_exact"] is True
     and s["replay_matches_live"] is True,
     f"{len(s['compactions'])} watermark passes")
raise SystemExit(1 if fails else 0)
EOG

echo "== serve suite (fixed-seed load gate) =="
python -m pytest -m serve -q

echo "== serve load benchmark (smoke, gated) =="
PYTHONPATH="src:." python benchmarks/serve_bench.py --smoke --gate

echo "== BENCH_serve.json =="
python - <<'EOS'
import json
rec = json.load(open("BENCH_serve.json"))
fails = []
def gate(name, cond, detail):
    print(f"  {'ok' if cond else 'FAIL'}: {name} ({detail})")
    if not cond:
        fails.append(name)

scenarios = ("steady", "bursty", "long_context", "multi_tenant",
             "cancel_heavy")
gate("scenarios present", all(f"scenario/{n}" in rec for n in scenarios),
     f"{sum(1 for n in scenarios if f'scenario/{n}' in rec)}/5")
# a rerun from the same seeds must be byte-identical
gate("determinism", rec["determinism"]["identical"] is True,
     f"{rec['determinism']['reruns']} passes identical")
for n in scenarios:
    s = rec[f"scenario/{n}"]
    gate(f"{n} ledger", s["conservation_ok"] is True,
         f"{s['done']}+{s['rejected']}+{s['cancelled']}=={s['submitted']}")
    gate(f"{n} progress", s["done"] > 0 and s["tokens_per_s"] > 0,
         f"{s['done']} done, {s['tokens_per_s']:.0f} tok/s")
    gate(f"{n} latency", s["p50_complete_steps"] <= s["p99_complete_steps"],
         f"p50={s['p50_complete_steps']} p99={s['p99_complete_steps']}")
    gate(f"{n} contiguity", 0.0 < s["contiguity"] <= 1.0,
         f"PUD-executable analogue {s['contiguity']:.3f}")
b, st = rec["scenario/bursty"], rec["scenario/steady"]
gate("bursty queues deeper", b["queue_depth_peak"] > st["queue_depth_peak"],
     f"{b['queue_depth_peak']} vs {st['queue_depth_peak']}")
gate("bursty preempts", b["preemptions"] > 0,
     f"{b['preemptions']} preemptions (recompute-on-resume exercised)")
gate("cancellations fire", rec["scenario/cancel_heavy"]["cancelled"] > 0,
     f"{rec['scenario/cancel_heavy']['cancelled']} cancelled")
mt = rec["scenario/multi_tenant"]
gate("tenant mix", mt["channels"] == 2
     and sum(1 for v in mt["done_by_tenant"].values() if v > 0) >= 2,
     f"{mt['channels']} channels, done_by_tenant={mt['done_by_tenant']}")
raise SystemExit(1 if fails else 0)
EOS

echo "== trace suite (golden-trace + replay gate) =="
python -m pytest -m trace -q

echo "== trace benchmark (smoke, gated) =="
PYTHONPATH="src:." python benchmarks/trace_bench.py --smoke --gate

echo "== BENCH_trace.json =="
python - <<'EOT'
import json
rec = json.load(open("BENCH_trace.json"))
fails = []
def gate(name, cond, detail):
    print(f"  {'ok' if cond else 'FAIL'}: {name} ({detail})")
    if not cond:
        fails.append(name)

# the trace bench regenerated everything twice: must be byte-identical
gate("determinism", rec["determinism"]["identical"] is True,
     f"{rec['determinism']['reruns']} passes identical")
archs = rec["config"]["archs"]
gate("coverage", len(archs) >= 3 and len(rec["config"]["allocators"]) == 4,
     f"{len(archs)} archs x {len(rec['config']['allocators'])} allocators")
for arch in archs:
    f = {al: rec[f"offload/{arch}/{al}"]["offload_fraction"]
         for al in ("malloc", "posix_memalign", "hugepage", "puma")}
    # the paper's allocator story at decode-step granularity: standard
    # interfaces offload ~nothing, hugepages partially, PUMA ~everything
    gate(f"{arch} malloc/posix offload ~0",
         f["malloc"] == 0.0 and f["posix_memalign"] == 0.0,
         f"malloc={f['malloc']} posix={f['posix_memalign']}")
    gate(f"{arch} hugepage partial", 0.0 < f["hugepage"] < 0.95,
         f"hugepage={f['hugepage']:.3f}")
    gate(f"{arch} puma strictly highest",
         f["puma"] >= 0.99 and all(f["puma"] > f[a] for a in
                                   ("malloc", "posix_memalign", "hugepage")),
         f"puma={f['puma']:.3f} > hugepage={f['hugepage']:.3f}")
    sp = rec[f"offload/{arch}/puma"]["speedup_vs_cpu"]
    gate(f"{arch} puma decode speedup", sp >= 1.5,
         f"{sp:.2f}x vs CPU-only decode")
    ch = rec[f"channel/{arch}"]
    gate(f"{arch} channel parallelism", ch["parallel_speedup"] >= 2.0,
         f"{ch['parallel_speedup']:.2f}x over serial at "
         f"{ch['channels']} channels")
sv = rec["serve/steady_trace"]
gate("serve trace replays bit-exact",
     sv["replay_ok"] is True and sv["replay_mismatches"] == 0,
     f"{sv['events']} events, sim_ns={sv['sim_ns']}")
raise SystemExit(1 if fails else 0)
EOT

if [[ "$FULL" == "1" ]]; then
  echo "== full-size lane: slow suite =="
  python -m pytest -m slow -q

  echo "== full-size lane: serve load benchmark (full, gated) =="
  PYTHONPATH="src:." python benchmarks/serve_bench.py --gate
fi
echo "CI OK"
