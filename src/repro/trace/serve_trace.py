"""Record a serving scenario into a ``repro.trace`` op trace.

This is the live half of the golden-trace loop: it builds the exact
engine :mod:`benchmarks.serve_bench` builds for a scenario (same smoke
model, same pool overrides, same watermark maintenance), attaches a
:class:`~repro.trace.record.TraceRecorder`, plays the scenario's
fixed-seed request stream through :func:`repro.serve.loadgen.play`, and
finalizes the trace with the engine's end-of-run totals.

Because every input is seed-pinned, the emitted JSONL is byte-identical
across runs and machines — that is what ``tests/test_trace_golden.py``
asserts against ``tests/goldens/``, and what lets
:func:`repro.trace.replay.replay_trace` re-price the run bit-exactly
without a model or engine in the loop.

Run as a module to (re)generate the golden deliberately::

    PYTHONPATH=src python -m repro.trace.serve_trace \
        --write-golden tests/goldens/steady_smoke.trace.jsonl
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional, Tuple

import numpy as np

from repro.trace.record import DEFAULT_SIM, TraceRecorder

_MODEL_CACHE: Tuple = ()


def _model():
    """Same shared smoke model as ``benchmarks/serve_bench.py``."""
    global _MODEL_CACHE
    if not _MODEL_CACHE:
        import jax

        from repro.configs.registry import get_config
        from repro.models.transformer import LM

        cfg = get_config("stablelm_1_6b").smoke()
        model = LM(cfg, attn_impl="naive", remat=None)
        params = model.init(jax.random.key(0))
        _MODEL_CACHE = (model, params)
    return _MODEL_CACHE


def record_scenario(
    name: str = "steady",
    *,
    smoke: bool = True,
    n_requests: Optional[int] = None,
) -> Tuple[TraceRecorder, Dict[str, object]]:
    """Play scenario ``name`` under a recorder; returns (trace, play record).

    ``n_requests`` truncates the scenario's request stream (keeping its
    seeds) — used by fast tests that want a handful of admits rather than
    the whole smoke run.
    """
    from repro.core.kv_pool import KVPoolConfig
    from repro.serve.engine import MaintenanceConfig, ServeEngine
    from repro.serve.loadgen import build_scenario, play

    model, params = _model()
    cfg = model.cfg
    sc = build_scenario(name, smoke=smoke)
    base = dict(
        num_blocks=32, block_size=8, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        n_layers=cfg.n_layers, max_seqs=4, max_blocks_per_seq=16,
        blocks_per_arena=16, policy="puma", dtype="float32",
    )
    base.update(sc.pool_overrides())
    pool_cfg = KVPoolConfig(**base)
    tile_bytes = (
        2 * pool_cfg.n_layers * pool_cfg.block_size * pool_cfg.kv_heads
        * pool_cfg.head_dim * np.dtype(pool_cfg.dtype).itemsize
    )
    trace = TraceRecorder(
        channels=pool_cfg.n_channels,
        banks_per_channel=8,
        blocks_per_arena=pool_cfg.blocks_per_arena,
        block_bytes=int(tile_bytes),
        sim=dict(DEFAULT_SIM),
        meta={
            "scenario": name,
            "seed": sc.seed,
            "smoke": bool(smoke),
            "model": "stablelm_1_6b.smoke",
            "policy": pool_cfg.policy,
        },
    )
    eng = ServeEngine(
        model, params, pool_cfg,
        use_kernel=False, maintenance=MaintenanceConfig(), trace=trace,
    )
    specs = sc.generate()
    if n_requests is not None:
        specs = specs[:n_requests]
    rec = play(eng, specs, max_steps=sc.max_steps)
    trace.finalize(
        clock=eng.clock,
        tokens_decoded=eng.tokens_decoded,
        tokens_prefilled=eng.tokens_prefilled,
        maintenance_ns=eng.maintenance_ns,
    )
    return trace, rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="steady")
    ap.add_argument("--full", action="store_true",
                    help="full-size scenario (default: smoke)")
    ap.add_argument("--write-golden", metavar="PATH", default=None,
                    help="write the canonical JSONL to PATH")
    args = ap.parse_args()
    trace, rec = record_scenario(args.scenario, smoke=not args.full)
    if args.write_golden:
        trace.write(args.write_golden)
        print(f"[serve_trace] wrote {args.write_golden} "
              f"({len(trace.events)} events)")
    else:
        print(f"[serve_trace] {args.scenario}: {len(trace.events)} events, "
              f"done={rec['done']}/{rec['submitted']}")


if __name__ == "__main__":
    main()
