"""Tracegen-style GEMV/MoE offload model: decode matvecs priced as PUD ops.

The roadmap question this answers: *what fraction of an LLM decode step is
PUD-executable under PUMA placement?*  Following HBM-PIMulator's Tracegen
(Model_GEMV / Mixtral): a decode step is a stream of matrix-vector products
— attention projections, the (routed, for MoE) MLP mats, and the LM head —
and each weight matrix maps onto DRAM banks row by row.  We price every
matvec as one ``mac`` op (:mod:`repro.core.pud`'s MIMDRAM/Proteus-style
arithmetic extension) over two operands:

* the **weight matrix** — ``n_out x d_in`` float32, the data that actually
  lives in DRAM and dominates decode bandwidth;
* a same-size **accumulator array** — MIMDRAM-style in-situ partial-sum
  bit-planes co-located with the weight rows (one partial-sum row per
  weight DRAM row), reduced by the mat peripherals.

A DRAM row of the weight matrix is PUD-executable iff both operands'
regions are contiguous, row-aligned, and share a global subarray — exactly
the paper's criterion, so the four allocator placements reproduce the §1
story at decode-step granularity: ``malloc``/``posix_memalign`` scatter
4 KB pages (0 %), ``hugepage`` co-locates only when two independent huge
pages happen to mirror subarrays (partial), PUMA's ``pim_alloc`` +
``pim_alloc_align`` co-locates by construction (~100 %).  Rows that fail
fall back to the CPU; the adaptive driver in ``simulate_op`` keeps the
baseline honest (an allocator with 0 % offload prices at exactly CPU
speed, never slower).

MoE expert dispatch: only the ``experts_per_tok`` routed experts' mats are
priced per token (seeded routing — same seed, same expert stream), after
the router matvec.  All experts' weights stay resident, as on hardware.

``gemv_execute`` is the functional counterpart: it computes ``W @ x`` by
partitioning W's output rows into in-DRAM and CPU-fallback groups per the
same placement plan and dispatching each group separately — bit-exact
against a whole-matrix ``jnp.dot`` (the property test drives this with
integer-valued float32 so accumulation order cannot introduce ULP noise).

``channel_study`` is the per-channel arm: PUMA channel-striped placement
on a multi-channel BANK_REGION map, ops dispatched through a live
:class:`~repro.core.controller.DramController` (with trace emission), so
bank-level parallelism and mode switches show up in the makespan.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.registry import TRACE_ARCHS, get_config
from repro.core import pud
from repro.core.allocators import (
    Allocation,
    HugePageModel,
    MallocModel,
    PhysicalMemory,
    PosixMemalignModel,
)
from repro.core.controller import DramController
from repro.core.dram import AddressMap, BANK_REGION_SCHEME, DramGeometry
from repro.core.puma import PumaAllocator

__all__ = [
    "ALLOCATORS",
    "TRACE_ARCHS",
    "weight_shapes",
    "decode_op_stream",
    "build_placement",
    "offload_report",
    "gemv_execute",
    "channel_study",
]

ITEMSIZE = 4  # float32 — decode weights in the smoke configs
ALLOCATORS: Tuple[str, ...] = ("malloc", "posix_memalign", "hugepage", "puma")


def weight_shapes(cfg) -> Dict[str, Tuple[int, int]]:
    """Every decode-path weight matrix of ``cfg`` as name -> (n_out, d_in).

    Names are stable and ordered (layer-major, module order), so placement
    and op streams derived from them are deterministic.
    """
    shapes: Dict[str, Tuple[int, int]] = {}
    d, H, KV, hd, ff = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    for li in range(cfg.n_layers):
        p = f"L{li}"
        shapes[f"{p}/attn/wq"] = (H * hd, d)
        shapes[f"{p}/attn/wk"] = (KV * hd, d)
        shapes[f"{p}/attn/wv"] = (KV * hd, d)
        shapes[f"{p}/attn/wo"] = (d, H * hd)
        if cfg.n_experts:
            shapes[f"{p}/moe/router"] = (cfg.n_experts, d)
            for e in range(cfg.n_experts):
                shapes[f"{p}/moe/e{e}/w_in"] = (ff, d)
                shapes[f"{p}/moe/e{e}/w_gate"] = (ff, d)
                shapes[f"{p}/moe/e{e}/w_out"] = (d, ff)
        elif cfg.activation == "swiglu":
            shapes[f"{p}/mlp/w_in"] = (ff, d)
            shapes[f"{p}/mlp/w_gate"] = (ff, d)
            shapes[f"{p}/mlp/w_out"] = (d, ff)
        else:
            shapes[f"{p}/mlp/w_in"] = (ff, d)
            shapes[f"{p}/mlp/w_out"] = (d, ff)
    shapes["lm_head"] = (cfg.vocab_size, d)
    return shapes


def decode_op_stream(cfg, *, seed: int = 0, n_tokens: int = 2) -> List[str]:
    """The matvec stream of ``n_tokens`` decode steps, as weight names.

    For MoE layers, each token routes to ``experts_per_tok`` experts drawn
    without replacement from a seeded generator (HBM-PIMulator's Mixtral
    trace does the same): the stream is deterministic in ``seed`` but
    different tokens activate different experts.
    """
    rng = np.random.default_rng(seed)
    ops: List[str] = []
    for _t in range(n_tokens):
        for li in range(cfg.n_layers):
            p = f"L{li}"
            ops += [f"{p}/attn/{w}" for w in ("wq", "wk", "wv", "wo")]
            if cfg.n_experts:
                ops.append(f"{p}/moe/router")
                routed = sorted(
                    int(e) for e in rng.choice(
                        cfg.n_experts, size=cfg.experts_per_tok, replace=False
                    )
                )
                for e in routed:
                    ops += [
                        f"{p}/moe/e{e}/{w}"
                        for w in ("w_in", "w_gate", "w_out")
                    ]
            elif cfg.activation == "swiglu":
                ops += [f"{p}/mlp/{w}" for w in ("w_in", "w_gate", "w_out")]
            else:
                ops += [f"{p}/mlp/w_in", f"{p}/mlp/w_out"]
        ops.append("lm_head")
    return ops


def build_placement(
    shapes: Dict[str, Tuple[int, int]],
    allocator: str,
    mem: PhysicalMemory,
    *,
    prealloc_huge: int = 32,
) -> Dict[str, Tuple[Allocation, Allocation]]:
    """Place every weight matrix (and its accumulator) with one allocator.

    malloc / posix_memalign / hugepage allocate weight and accumulator as
    two independent requests — exactly what a library calling the standard
    interfaces gets.  PUMA allocates the weight with ``pim_alloc`` and the
    accumulator with ``pim_alloc_align`` against it, the paper's
    co-location API.
    """
    placement: Dict[str, Tuple[Allocation, Allocation]] = {}
    if allocator == "puma":
        pa = PumaAllocator(mem)
        pa.pim_preallocate(prealloc_huge)
        for name, (n_out, d_in) in shapes.items():
            nbytes = n_out * d_in * ITEMSIZE
            w = pa.pim_alloc(nbytes)
            acc = None if w is None else pa.pim_alloc_align(nbytes, w)
            if w is None or acc is None:
                raise MemoryError(
                    f"PUMA pool exhausted placing {name} "
                    f"({nbytes} bytes; raise prealloc_huge)"
                )
            placement[name] = (w, acc)
        return placement
    mk = {
        "malloc": lambda m: MallocModel(m),
        "posix_memalign": lambda m: PosixMemalignModel(m),
        "hugepage": lambda m: HugePageModel(m, "mmap"),
    }[allocator]
    al = mk(mem)
    for name, (n_out, d_in) in shapes.items():
        nbytes = n_out * d_in * ITEMSIZE
        placement[name] = (al.alloc(nbytes), al.alloc(nbytes))
    return placement


def offload_report(
    arch: str,
    allocator: str,
    *,
    seed: int = 0,
    n_tokens: int = 2,
    model: Optional[pud.PudCostModel] = None,
    recorder=None,
) -> Dict[str, object]:
    """Price ``n_tokens`` decode steps of ``arch`` (smoke config) under one
    allocator placement: PUD-offloaded row fraction + SimCost-style speedup
    of the adaptive PUD driver over CPU-only decode.

    Uses the default (cacheline-interleaved, 8 KB-region) address map —
    the same one the §1 fraction study (``benchmarks/alloc_fraction.py``)
    reports on, so the numbers compose with the paper's.
    """
    cfg = get_config(arch).smoke()
    amap = AddressMap()
    mem = PhysicalMemory(amap, seed=seed)
    shapes = weight_shapes(cfg)
    placement = build_placement(shapes, allocator, mem)
    stream = decode_op_stream(cfg, seed=seed, n_tokens=n_tokens)
    mdl = model or pud.PudCostModel()
    rows = rows_pud = 0
    t_ns = t_cpu_ns = 0.0
    for name in stream:
        w, acc = placement[name]
        plan = pud.plan_rows("mac", [w, acc], amap)
        rows += plan.n_rows
        rows_pud += sum(plan.in_pud)
        res = pud.simulate_op(
            "mac", [w, acc], amap, mdl,
            recorder=recorder, label=f"{arch}/{allocator}/{name}",
        )
        t_ns += res.t_ns
        t_cpu_ns += res.t_cpu_ns
    return {
        "arch": arch,
        "allocator": allocator,
        "n_tokens": n_tokens,
        "n_weights": len(shapes),
        "n_ops": len(stream),
        "moe": cfg.n_experts > 0,
        "experts_per_tok": cfg.experts_per_tok,
        "rows": rows,
        "rows_pud": rows_pud,
        "offload_fraction": round(rows_pud / rows, 6) if rows else 0.0,
        "decode_ns": round(t_ns, 3),
        "decode_cpu_ns": round(t_cpu_ns, 3),
        "speedup_vs_cpu": round(t_cpu_ns / t_ns, 4) if t_ns else 1.0,
    }


def gemv_execute(
    w: np.ndarray,
    x: np.ndarray,
    w_alloc: Allocation,
    acc_alloc: Allocation,
    amap: AddressMap,
) -> np.ndarray:
    """Compute ``y = W @ x`` dispatching W's rows per the placement plan.

    Output rows whose DRAM row is PUD-executable compute as one group (the
    in-DRAM mac), the rest as another (CPU fallback) — scattered back into
    one result.  Both groups use ``jnp.dot``, so the test invariant is that
    *partitioned* dispatch is bit-exact against the whole-matrix product.
    A W row is attributed to the DRAM row holding its first byte (W rows
    divide the 8 KB region evenly for every power-of-two ``d_in`` here).
    """
    import jax.numpy as jnp

    w = np.asarray(w)
    n_out, d_in = w.shape
    plan = pud.plan_rows("mac", [w_alloc, acc_alloc], amap)
    y = np.zeros((n_out,), dtype=w.dtype)
    if plan.n_rows == 0:
        return y
    mask = np.asarray(plan.in_pud, dtype=bool)
    bytes_per_wrow = d_in * w.dtype.itemsize
    dram_row = (np.arange(n_out, dtype=np.int64) * bytes_per_wrow
                ) // amap.region_bytes
    dram_row = np.minimum(dram_row, plan.n_rows - 1)
    wmask = mask[dram_row]
    xj = jnp.asarray(x)
    for m in (wmask, ~wmask):
        idx = np.flatnonzero(m)
        if idx.size:
            y[idx] = np.asarray(jnp.dot(jnp.asarray(w[idx]), xj))
    return y


def channel_study(
    arch: str,
    *,
    channels: int = 4,
    seed: int = 0,
    n_tokens: int = 1,
    model: Optional[pud.PudCostModel] = None,
    recorder=None,
) -> Dict[str, object]:
    """Per-channel arm: PUMA channel-striped weights on a ``channels``-wide
    BANK_REGION map, the mac stream dispatched through a live
    :class:`~repro.core.controller.DramController` (trace-recorded when a
    ``recorder`` is passed).  Reports the makespan, per-channel balance,
    and the parallel gain over a serial single-channel burst.
    """
    cfg = get_config(arch).smoke()
    amap = AddressMap(
        DramGeometry(channels=channels, subarrays_per_bank=128),
        BANK_REGION_SCHEME,
    )
    mem = PhysicalMemory(amap, seed=seed, n_huge_pages=128, huge_scatter=1.0)
    pa = PumaAllocator(mem, amap, stripe_channels=True)
    pa.pim_preallocate(64)
    placement: Dict[str, Tuple[Allocation, Allocation]] = {}
    for name, (n_out, d_in) in weight_shapes(cfg).items():
        nbytes = n_out * d_in * ITEMSIZE
        w = pa.pim_alloc(nbytes)
        acc = None if w is None else pa.pim_alloc_align(nbytes, w)
        if w is None or acc is None:
            raise MemoryError(f"PUMA channel pool exhausted placing {name}")
        placement[name] = (w, acc)
    mdl = model or pud.PudCostModel()
    dram = DramController(amap, recorder=recorder)
    rows = rows_pud = 0
    for name in decode_op_stream(cfg, seed=seed, n_tokens=n_tokens):
        w, acc = placement[name]
        plan = pud.plan_rows("mac", [w, acc], amap)
        rows += plan.n_rows
        rows_pud += sum(plan.in_pud)
        pud.simulate_op(
            "mac", [w, acc], amap, mdl, controller=dram,
            recorder=recorder, label=f"{arch}/puma/{name}",
        )
    rep = dram.occupancy_report()
    dispatched = int(sum(rep["pud_rows"]))
    serial_ns = dispatched * mdl.pud_row_ns("mac")
    makespan = float(rep["makespan_ns"])
    return {
        "arch": arch,
        "channels": channels,
        "rows": rows,
        "rows_pud": rows_pud,
        "rows_dispatched": dispatched,
        "offload_fraction": round(rows_pud / rows, 6) if rows else 0.0,
        "makespan_ns": round(makespan, 3),
        "serial_ns": round(serial_ns, 3),
        "parallel_speedup": (
            round(serial_ns / makespan, 4) if makespan else 1.0
        ),
        "balance": round(float(rep["pud_row_balance"]), 4),
        "mode_switches": rep["mode_switches"],
    }
