"""repro.trace — trace-driven PIM offload of decode GEMV/MoE (ISSUE 10).

Three pieces, mirroring HBM-PIMulator's Tracegen design:

* :mod:`repro.trace.record` — a versioned, seed-deterministic tracegen
  recorder that hooks :class:`~repro.serve.engine.ServeEngine`,
  :class:`~repro.core.kv_pool.PagedKVPool` and
  :class:`~repro.core.controller.DramController` and emits a per-channel
  op trace (row-copy bursts, AND/OR/NOT/MAC PUD ops, read/write bursts,
  CPU fallbacks) as JSONL with a pinned schema.
* :mod:`repro.trace.replay` — a replay executor that re-prices a trace
  through :mod:`repro.core.pud` + :mod:`repro.core.controller` bit-exactly,
  independent of the live engine.
* :mod:`repro.trace.gemv` — a Tracegen-style GEMV/MoE offload model that
  maps registry-model decode matvecs onto banks and classifies each op as
  PUD-executable vs CPU fallback under the four allocator placements.

:mod:`repro.trace.serve_trace` glues the recorder onto the fixed-seed
serving scenarios and owns the golden-trace writer.
"""
from repro.trace.record import SCHEMA_VERSION, TraceRecorder, TraceSchemaError
from repro.trace.replay import ReplayResult, parse_trace, replay_trace

__all__ = [
    "SCHEMA_VERSION",
    "TraceRecorder",
    "TraceSchemaError",
    "ReplayResult",
    "parse_trace",
    "replay_trace",
]
