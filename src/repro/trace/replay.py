"""Replay executor: re-price a recorded trace bit-exactly, offline.

``replay_trace`` rebuilds the cost models from the trace *header alone*
(:class:`~repro.core.pud.PudCostModel`, :class:`~repro.core.controller.
ControllerConfig`, fresh :class:`~repro.core.controller.ChannelController`
state) and walks the events in order, recomputing every priced field —
RowClone burst completion times, FR-FCFS access bursts, ``pud_op`` times
through the same arithmetic :func:`repro.core.pud.simulate_op` uses, and
the run totals — then compares each against the recorded value with exact
``==`` (all floats round-trip through JSON losslessly, and the replay
performs the identical operations on identical doubles, so bit-exact
equality is the contract, not a tolerance).

The replayer is deliberately independent of the live engine: it never
imports :mod:`repro.serve` and needs no model, params, or allocator state.
A trace that replays clean is therefore a self-contained, re-priceable
artifact; a mismatch list pinpoints exactly which event and field drifted
(the loud failure mode the golden-trace test wants).

Controller state is split exactly as in recording: the header's
``channels`` controllers price kv traffic (``prefill``/``step`` events),
while ``ctrl_pud``/``ctrl_access`` events replay against a separate bank
of controllers sized from the events themselves (mirroring the live
:class:`~repro.core.controller.DramController` the ops were dispatched
through).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.controller import ChannelController, ControllerConfig
from repro.core.pud import PudCostModel
from repro.trace.record import SCHEMA_VERSION, TraceSchemaError, tile_runs

__all__ = ["ReplayResult", "parse_trace", "replay_trace"]


@dataclasses.dataclass
class ReplayResult:
    ok: bool
    n_events: int
    mismatches: List[str]
    totals: Optional[Dict[str, object]]      # recorded end-event totals
    recomputed: Dict[str, object]            # replayed counters/totals

    def report(self, limit: int = 20) -> str:
        if self.ok:
            return f"replay ok: {self.n_events} events bit-exact"
        head = self.mismatches[:limit]
        more = len(self.mismatches) - len(head)
        lines = [f"replay FAILED: {len(self.mismatches)} mismatches over "
                 f"{self.n_events} events"] + [f"  {m}" for m in head]
        if more > 0:
            lines.append(f"  ... and {more} more")
        return "\n".join(lines)


def parse_trace(text: str) -> List[Dict[str, object]]:
    """Parse JSONL and validate the header against the pinned schema."""
    events = [json.loads(line) for line in text.splitlines() if line.strip()]
    if not events or events[0].get("kind") != "header":
        raise TraceSchemaError("trace does not start with a header event")
    schema = events[0].get("schema")
    if schema != SCHEMA_VERSION:
        raise TraceSchemaError(
            f"trace schema {schema!r} != pinned SCHEMA_VERSION "
            f"{SCHEMA_VERSION} — regenerate the trace (and the golden, "
            f"deliberately) or replay with the matching repro.trace version"
        )
    return events


def replay_trace(
    trace: Union[str, Sequence[Dict[str, object]]]
) -> ReplayResult:
    """Re-price ``trace`` (JSONL text or parsed events) event by event."""
    events = parse_trace(trace) if isinstance(trace, str) else list(trace)
    header = events[0]
    model = PudCostModel(**header["model"])
    ctrl_cfg = ControllerConfig(**header["ctrl"])
    channels = int(header["channels"])
    banks = int(header["banks_per_channel"])
    bpa = int(header["blocks_per_arena"])
    block_bytes = int(header["block_bytes"])
    sim = header["sim"]

    kv_ctrls = [ChannelController(c, ctrl_cfg) for c in range(channels)]
    now_ns = 0.0
    cpu_ns = 0.0
    # separate controller bank for DramController-dispatched events
    dram_ctrls: List[ChannelController] = []
    dram_now = 0.0

    clock = 0
    tokens_decoded = 0
    tokens_prefilled = 0
    maintenance_ns = 0.0
    mismatches: List[str] = []
    totals: Optional[Dict[str, object]] = None

    def check(i: int, kind: str, field: str, recorded, replayed) -> None:
        if recorded != replayed:
            mismatches.append(
                f"event {i} ({kind}): {field}: recorded {recorded!r} "
                f"!= replayed {replayed!r}"
            )

    def need_dram(n: int) -> None:
        nonlocal dram_ctrls
        if not dram_ctrls:
            dram_ctrls = [ChannelController(c, ctrl_cfg) for c in range(n)]
        elif len(dram_ctrls) != n:
            mismatches.append(
                f"ctrl events disagree on channel count: "
                f"{len(dram_ctrls)} vs {n}"
            )

    for ev in events[1:]:
        i, kind = ev["i"], ev["kind"]
        if kind in ("admit", "extend", "release"):
            continue

        elif kind == "prefill":
            tiles = [int(t) for t in ev["tiles"]]
            runs = tile_runs(tiles)
            rowclone = [t for start, n in runs if n >= 2
                        for t in range(start, start + n)]
            cpu_tiles = [start for start, n in runs if n == 1]
            check(i, kind, "rowclone_rows", ev["rowclone_rows"], len(rowclone))
            check(i, kind, "cpu_rows", ev["cpu_rows"], len(cpu_tiles))
            counts = [0] * channels
            for t in rowclone:
                counts[(t // bpa) % channels] += 1
            check(i, kind, "rows_per_channel", ev["rows_per_channel"], counts)
            start = now_ns
            done = start
            row_ns = model.pud_row_ns("copy")
            for c, n in enumerate(counts):
                if n:
                    done = max(done, kv_ctrls[c].enqueue_pud(n, row_ns, start))
            now_ns = max(now_ns, done)
            c_ns = 0.0
            if cpu_tiles:
                c_ns = model.cpu_op_overhead_ns + model.cpu_ns(
                    "copy", len(cpu_tiles) * block_bytes, len(cpu_tiles)
                )
            cpu_ns += c_ns
            check(i, kind, "start", ev["start"], start)
            check(i, kind, "done", ev["done"], done)
            check(i, kind, "cpu_ns", ev["cpu_ns"], c_ns)
            tokens_prefilled += int(ev["tokens"])

        elif kind == "step":
            per: List[List[Tuple[int, int]]] = [[] for _ in range(channels)]
            for _slot, tile in ev["writes"]:
                arena = int(tile) // bpa
                bank = (arena // channels) % banks
                per[arena % channels].append((bank, int(tile)))
            start = now_ns
            done = start
            for c, pairs in enumerate(per):
                if pairs:
                    done = max(
                        done, kv_ctrls[c].enqueue_accesses(pairs, start)
                    )
            now_ns = max(now_ns, done)
            check(i, kind, "start", ev["start"], start)
            check(i, kind, "done", ev["done"], done)
            clock = int(ev["clock"])
            tokens_decoded += int(ev["decoded"])

        elif kind == "compact":
            if int(ev["executed"]):  # mirrors the engine's accounting guard
                maintenance_ns += float(ev["total_ns"])

        elif kind == "pud_op":
            op = ev["op"]
            pud_rows = int(ev["pud_rows"])
            cpu_rows = int(ev["cpu_rows"])
            rpc = ev["rows_per_channel"]
            row_ns = model.pud_row_ns(op)
            t: Optional[float]
            if pud_rows and rpc is not None:
                check(i, kind, "pud_rows", pud_rows, sum(rpc))
                if ev["ctrl"]:
                    need_dram(len(rpc))
                    start = dram_now
                    done = start
                    for c, n in enumerate(rpc):
                        if n:
                            done = max(
                                done,
                                dram_ctrls[c].peek_pud(int(n), row_ns, start),
                            )
                    t = done - start
                else:
                    t = int(max(rpc)) * row_ns
            elif pud_rows:
                t = None          # adaptive driver picked the CPU
            else:
                t = 0.0
            if t is not None:
                if cpu_rows:
                    t += model.cpu_op_overhead_ns
                    t += model.cpu_ns(op, int(ev["cpu_bytes"]), cpu_rows)
                elif pud_rows:
                    t += model.cpu_op_overhead_ns
            t_cpu = model.cpu_op_overhead_ns + model.cpu_ns(
                op, int(ev["size"]), max(int(ev["n_rows"]), 1)
            )
            if t is None:
                t = t_cpu
            faulted = int(ev["faulted_rows"])
            if faulted and rpc is not None:
                if not cpu_rows:
                    t += model.cpu_op_overhead_ns
                t += model.cpu_ns(
                    op, faulted * int(ev["region_bytes"]), faulted
                )
            check(i, kind, "t_ns", ev["t_ns"], t)
            check(i, kind, "t_cpu_ns", ev["t_cpu_ns"], t_cpu)

        elif kind == "ctrl_pud":
            rpc = [int(n) for n in ev["rows_per_channel"]]
            need_dram(len(rpc))
            row_ns = float(ev["row_ns"])
            start = dram_now
            done = start
            for c, n in enumerate(rpc):
                if n:
                    done = max(
                        done, dram_ctrls[c].enqueue_pud(n, row_ns, start)
                    )
            dram_now = max(dram_now, done)
            check(i, kind, "start", ev["start"], start)
            check(i, kind, "done", ev["done"], done)

        elif kind == "ctrl_access":
            need_dram(int(ev["channels"]))
            start = dram_now
            done = start
            for c in range(len(dram_ctrls)):
                pairs = [
                    (int(b), int(r)) for ch, b, r in ev["accesses"]
                    if int(ch) == c
                ]
                if pairs:
                    done = max(
                        done, dram_ctrls[c].enqueue_accesses(pairs, start)
                    )
            dram_now = max(dram_now, done)
            check(i, kind, "start", ev["start"], start)
            check(i, kind, "done", ev["done"], done)

        elif kind == "end":
            totals = {k: v for k, v in ev.items() if k not in ("i", "kind")}
            check(i, kind, "clock", ev["clock"], clock)
            check(i, kind, "tokens_decoded", ev["tokens_decoded"],
                  tokens_decoded)
            check(i, kind, "tokens_prefilled", ev["tokens_prefilled"],
                  tokens_prefilled)
            check(i, kind, "maintenance_ns", ev["maintenance_ns"],
                  maintenance_ns)
            sim_ns = (
                sim["step_overhead_ns"] * int(ev["clock"])
                + sim["decode_token_ns"] * int(ev["tokens_decoded"])
                + sim["prefill_token_ns"] * int(ev["tokens_prefilled"])
                + float(ev["maintenance_ns"])
            )
            check(i, kind, "sim_ns", ev["sim_ns"], sim_ns)
            check(i, kind, "mem_ns", ev["mem_ns"], now_ns)
            check(i, kind, "cpu_ns", ev["cpu_ns"], cpu_ns)

        else:
            mismatches.append(f"event {i}: unknown kind {kind!r}")

    recomputed = {
        "clock": clock,
        "tokens_decoded": tokens_decoded,
        "tokens_prefilled": tokens_prefilled,
        "maintenance_ns": maintenance_ns,
        "mem_ns": now_ns,
        "cpu_ns": cpu_ns,
        "sim_ns": (
            sim["step_overhead_ns"] * clock
            + sim["decode_token_ns"] * tokens_decoded
            + sim["prefill_token_ns"] * tokens_prefilled
            + maintenance_ns
        ),
    }
    return ReplayResult(
        ok=not mismatches,
        n_events=len(events),
        mismatches=mismatches,
        totals=totals,
        recomputed=recomputed,
    )
