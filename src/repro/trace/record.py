"""Tracegen recorder: the serving engine's memory ops as a replayable trace.

One :class:`TraceRecorder` instance hooks three layers at once:

* :class:`~repro.serve.engine.ServeEngine` / :class:`~repro.core.kv_pool.
  PagedKVPool` — request lifecycle (admit / extend / release), prompt-KV
  block fills (RowClone row-copy bursts for contiguous tile runs, CPU
  fallback for singleton tiles), decode-token block writes (per-channel
  FR-FCFS read/write bursts), and compaction passes.
* :mod:`repro.core.pud` — every ``simulate_op`` call (the GEMV/MoE offload
  model's MAC stream) lands as one ``pud_op`` event carrying the full
  pricing breakdown: PUD rows per channel, CPU-fallback rows/bytes,
  chosen-path time vs CPU-only time, allocator provenance via ``label``.
* :class:`~repro.core.controller.DramController` — channel-level dispatch
  (``ctrl_pud`` / ``ctrl_access``) with per-channel row counts and
  (channel, bank, row) coordinates.

The trace is JSONL with a pinned schema (:data:`SCHEMA_VERSION`): line 0 is
a ``header`` event carrying the schema version, the channel/bank geometry,
and every cost-model constant needed to re-price the trace from scratch;
each subsequent line is one event with a monotonic index ``i``; an optional
``end`` event carries the run totals.  Every field is a JSON scalar/list
and every float is serialized at full precision (shortest round-trip repr),
so *byte-identical regeneration* and *bit-exact replay*
(:mod:`repro.trace.replay`) are both meaningful invariants — the golden
trace under ``tests/goldens/`` pins them in CI.

Pricing inside the recorder reuses :class:`~repro.core.controller.
ChannelController` directly (one per channel, same FR-FCFS-lite / mode-
switch model the DRAM controller uses), so the kv-traffic timings in the
trace are the controller model's numbers, not a parallel implementation.
KV traffic is priced at *tile* granularity: one pool tile ≙ one DRAM row
of its arena ("subarray"), the channel is ``arena % channels`` and the bank
``(arena // channels) % banks_per_channel`` — the same mapping
:class:`~repro.core.arena.TilePool` stripes by.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.controller import ChannelController, ControllerConfig
from repro.core.pud import PudCostModel

__all__ = [
    "SCHEMA_VERSION",
    "TraceSchemaError",
    "TraceRecorder",
    "tile_runs",
]

#: Pinned trace schema. Bump on ANY change to event kinds, field names, or
#: pricing semantics — the golden-trace test and the replay executor both
#: refuse traces whose header disagrees.
SCHEMA_VERSION = 1

#: default serving-time model constants mirrored into the header
#: (must match :class:`repro.serve.loadgen.SimCost`; serve_trace passes the
#: live values — these are only the stand-alone-recorder defaults).
DEFAULT_SIM = {
    "step_overhead_ns": 2_000.0,
    "decode_token_ns": 500.0,
    "prefill_token_ns": 150.0,
}


class TraceSchemaError(ValueError):
    """A trace's header does not match the pinned schema."""


def tile_runs(tiles: Sequence[int]) -> List[Tuple[int, int]]:
    """Maximal (start, length) runs of consecutive tile indices — the same
    partition :meth:`repro.core.arena.TileHandle.runs` produces."""
    out: List[Tuple[int, int]] = []
    i = 0
    n = len(tiles)
    while i < n:
        j = i
        while j + 1 < n and tiles[j + 1] == tiles[j] + 1:
            j += 1
        out.append((tiles[i], j - i + 1))
        i = j + 1
    return out


class TraceRecorder:
    """Versioned, seed-deterministic per-channel op trace (JSONL)."""

    def __init__(
        self,
        *,
        channels: int = 1,
        banks_per_channel: int = 8,
        blocks_per_arena: int = 1,
        block_bytes: int = 0,
        model: Optional[PudCostModel] = None,
        ctrl: Optional[ControllerConfig] = None,
        sim: Optional[Dict[str, float]] = None,
        meta: Optional[Dict[str, object]] = None,
    ):
        self.model = model or PudCostModel()
        self.ctrl_cfg = ctrl or ControllerConfig()
        self.sim = dict(DEFAULT_SIM)
        if sim:
            self.sim.update(sim)
        self.channels = int(channels)
        self.banks_per_channel = int(banks_per_channel)
        self.blocks_per_arena = int(blocks_per_arena)
        self.block_bytes = int(block_bytes)
        # the kv-traffic pricing state: one controller per channel, same
        # model the DRAM controller uses (ctrl_* events keep their own).
        self.ctrls = [
            ChannelController(c, self.ctrl_cfg) for c in range(self.channels)
        ]
        self.now_ns = 0.0       # in-DRAM frontier (max completion so far)
        self.cpu_ns = 0.0       # accumulated CPU-fallback time
        self.events: List[Dict[str, object]] = []
        self._emit_header(meta or {})

    # -- event plumbing ------------------------------------------------------
    def _emit_header(self, meta: Dict[str, object]) -> None:
        m, c = self.model, self.ctrl_cfg
        self.emit(
            "header",
            schema=SCHEMA_VERSION,
            channels=self.channels,
            banks_per_channel=self.banks_per_channel,
            blocks_per_arena=self.blocks_per_arena,
            block_bytes=self.block_bytes,
            model={
                "aap_ns": m.aap_ns,
                "pud_issue_ns": m.pud_issue_ns,
                "cpu_bw_gbs": m.cpu_bw_gbs,
                "cpu_op_overhead_ns": m.cpu_op_overhead_ns,
                "cpu_row_touch_ns": m.cpu_row_touch_ns,
            },
            ctrl={
                "mode_switch_ns": c.mode_switch_ns,
                "row_hit_ns": c.row_hit_ns,
                "row_miss_ns": c.row_miss_ns,
                "cacheline_bytes": c.cacheline_bytes,
            },
            sim=self.sim,
            meta=meta,
        )

    def emit(self, kind: str, **fields) -> Dict[str, object]:
        """Append one event; ``i`` is the monotonic per-trace index."""
        ev: Dict[str, object] = {"i": len(self.events), "kind": kind}
        ev.update(fields)
        self.events.append(ev)
        return ev

    # -- kv-pool / engine hooks ----------------------------------------------
    def on_admit(self, slot: int, tiles: Sequence[int], alloc: str) -> None:
        self.emit(
            "admit", slot=int(slot), tiles=[int(t) for t in tiles],
            alloc=alloc,
        )

    def on_extend(self, slot: int, tile: int, contig: bool) -> None:
        """One decode-time growth block; ``contig`` = the new tile extends
        the previous run (PUMA ``extend`` hit its adjacent slot)."""
        self.emit(
            "extend", slot=int(slot), tile=int(tile), contig=bool(contig),
        )

    def on_release(self, slot: int) -> None:
        self.emit("release", slot=int(slot))

    def on_prefill(
        self, slot: int, rid: int, tokens: int, tiles: Sequence[int]
    ) -> None:
        """Prompt-KV block fill: contiguous tile runs are RowClone row
        copies (one row per tile, executed channel-parallel by owning
        arena), singleton tiles fall back to a CPU streaming write."""
        runs = tile_runs([int(t) for t in tiles])
        rowclone = [t for start, n in runs if n >= 2
                    for t in range(start, start + n)]
        cpu_tiles = [start for start, n in runs if n == 1]
        counts = [0] * self.channels
        for t in rowclone:
            counts[(t // self.blocks_per_arena) % self.channels] += 1
        start_ns = self.now_ns
        done = start_ns
        row_ns = self.model.pud_row_ns("copy")
        for c, n in enumerate(counts):
            if n:
                done = max(done, self.ctrls[c].enqueue_pud(n, row_ns, start_ns))
        self.now_ns = max(self.now_ns, done)
        cpu_ns = 0.0
        if cpu_tiles:
            cpu_ns = self.model.cpu_op_overhead_ns + self.model.cpu_ns(
                "copy", len(cpu_tiles) * self.block_bytes, len(cpu_tiles)
            )
        self.cpu_ns += cpu_ns
        self.emit(
            "prefill",
            slot=int(slot), rid=int(rid), tokens=int(tokens),
            tiles=[int(t) for t in tiles],
            rowclone_rows=len(rowclone), cpu_rows=len(cpu_tiles),
            rows_per_channel=counts, start=start_ns, done=done,
            cpu_ns=cpu_ns,
        )

    def on_step(
        self, clock: int, decoded: int, writes: Sequence[Tuple[int, int]]
    ) -> None:
        """One engine tick: each decoded token's KV lands in its sequence's
        current block — a normal (bank, row) access burst per channel."""
        per: List[List[Tuple[int, int]]] = [[] for _ in range(self.channels)]
        for _slot, tile in writes:
            arena = int(tile) // self.blocks_per_arena
            bank = (arena // self.channels) % self.banks_per_channel
            per[arena % self.channels].append((bank, int(tile)))
        start_ns = self.now_ns
        done = start_ns
        for c, pairs in enumerate(per):
            if pairs:
                done = max(done, self.ctrls[c].enqueue_accesses(pairs, start_ns))
        self.now_ns = max(self.now_ns, done)
        self.emit(
            "step",
            clock=int(clock), decoded=int(decoded),
            writes=[[int(s), int(t)] for s, t in writes],
            start=start_ns, done=done,
        )

    def on_compact(self, moves: Sequence[Tuple[int, int]], report) -> None:
        """One executed compaction pass (already priced by the compaction
        engine — the event carries the outcome, replay sums the cost)."""
        self.emit(
            "compact",
            moves=[[int(s), int(d)] for s, d in moves],
            executed=int(report.executed),
            rowclone_rows=int(report.rowclone_rows),
            cpu_rows=int(report.cpu_rows),
            bytes_moved=int(report.bytes_moved),
            total_ns=float(report.total_ns),
        )

    # -- totals --------------------------------------------------------------
    def finalize(
        self,
        *,
        clock: int,
        tokens_decoded: int,
        tokens_prefilled: int,
        maintenance_ns: float,
    ) -> Dict[str, object]:
        """Close the trace with the run totals.  ``sim_ns`` follows
        :meth:`repro.serve.loadgen.SimCost.total_ns` term for term (same
        left-associated sum — bit-exact against the live engine)."""
        s = self.sim
        sim_ns = (
            s["step_overhead_ns"] * clock
            + s["decode_token_ns"] * tokens_decoded
            + s["prefill_token_ns"] * tokens_prefilled
            + maintenance_ns
        )
        totals = {
            "clock": int(clock),
            "tokens_decoded": int(tokens_decoded),
            "tokens_prefilled": int(tokens_prefilled),
            "maintenance_ns": float(maintenance_ns),
            "sim_ns": sim_ns,
            "mem_ns": self.now_ns,
            "cpu_ns": self.cpu_ns,
            "events": len(self.events) + 1,
        }
        self.emit("end", **totals)
        return totals

    # -- serialization -------------------------------------------------------
    def to_jsonl(self) -> str:
        """Canonical JSONL: sorted keys, no whitespace, one event per line.
        Floats use the shortest round-trip repr, so parse→serialize is the
        identity and byte-identity is a meaningful regression check."""
        return "".join(
            json.dumps(e, sort_keys=True, separators=(",", ":")) + "\n"
            for e in self.events
        )

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
