"""Fault-tolerant training loop.

Production behaviours implemented (CPU-scale here, same control flow at pod
scale):

* **checkpoint/restart** — atomic checkpoints every ``ckpt_every`` steps;
  on start, auto-resume from the latest complete one (params, optimizer
  moments, data step counter).
* **failure recovery** — a step that raises (injectable via
  ``failure_hook`` for tests) rolls back to the last checkpoint and replays;
  the deterministic data pipeline makes the replay bit-exact.
* **straggler mitigation** — per-step wall time is tracked with an EMA;
  steps slower than ``straggler_factor`` x EMA are logged and counted, the
  hook where a pod-scale deployment triggers hot-spare swap.
* **elastic re-shard** — checkpoints store canonical (unsharded) arrays, so
  ``Trainer`` can be restarted with a different mesh and the restore path
  re-shards (see ckpt.checkpoint docstring).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, DataIterator
from repro.optim import adamw as opt_mod
from repro.train.step import build_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    accum_steps: int = 1
    grad_compression: bool = False
    straggler_factor: float = 3.0


class Trainer:
    def __init__(
        self,
        model,
        data_cfg: DataConfig,
        opt_cfg: opt_mod.AdamWConfig,
        tcfg: TrainerConfig,
        *,
        failure_hook: Optional[Callable[[int], None]] = None,
        log: Callable[[str], None] = print,
    ):
        self.model = model
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.failure_hook = failure_hook
        self.log = log
        self.step_fn = jax.jit(
            build_train_step(
                model, opt_cfg,
                accum_steps=tcfg.accum_steps,
                grad_compression=tcfg.grad_compression,
            ),
            donate_argnums=(0, 1),
        )
        self.metrics_history: list = []
        self.straggler_steps = 0
        self.recoveries = 0

    # -- state management -------------------------------------------------------
    def _fresh_state(self):
        params = self.model.init(jax.random.key(0))
        return params, opt_mod.init_opt_state(params)

    def _save(self, step, params, opt_state):
        ckpt.save(
            self.tcfg.ckpt_dir, step,
            {"params": params, "opt": opt_state},
            keep=self.tcfg.ckpt_keep,
        )

    def _try_resume(self):
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        params, opt_state = self._fresh_state()
        if last is None:
            return 0, params, opt_state
        like = {"params": params, "opt": opt_state}
        state = ckpt.restore(self.tcfg.ckpt_dir, last, like)
        self.log(f"[trainer] resumed from step {last}")
        return last, state["params"], state["opt"]

    # -- the loop -----------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        start_step, params, opt_state = self._try_resume()
        it = DataIterator(self.data_cfg, dp_rank=0, start_step=start_step)
        ema = None
        step = start_step
        try:
            while step < self.tcfg.total_steps:
                step, np_batch = next(it)
                if step >= self.tcfg.total_steps:
                    break
                batch = {k: jax.numpy.asarray(v) for k, v in np_batch.items()}
                t0 = time.perf_counter()
                try:
                    if self.failure_hook is not None:
                        self.failure_hook(step)
                    params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                    metrics = {k: float(v) for k, v in metrics.items()}
                except Exception as e:  # noqa: BLE001 — node-failure recovery path
                    self.log(f"[trainer] step {step} failed ({e!r}); recovering")
                    self.recoveries += 1
                    it.close()
                    start_step, params, opt_state = self._try_resume()
                    it = DataIterator(self.data_cfg, dp_rank=0, start_step=start_step)
                    step = start_step
                    continue
                dt = time.perf_counter() - t0
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
                if dt > self.tcfg.straggler_factor * ema:
                    self.straggler_steps += 1
                    self.log(f"[trainer] straggler step {step}: {dt:.3f}s vs ema {ema:.3f}s")
                metrics["step_time"] = dt
                self.metrics_history.append((step, metrics))
                if step % self.tcfg.log_every == 0:
                    self.log(
                        f"[trainer] step {step} loss={metrics['loss']:.4f} "
                        f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.0f}ms"
                    )
                if (step + 1) % self.tcfg.ckpt_every == 0:
                    self._save(step + 1, params, opt_state)
            self._save(self.tcfg.total_steps, params, opt_state)
        finally:
            it.close()
        return {
            "params": params,
            "opt": opt_state,
            "history": self.metrics_history,
            "stragglers": self.straggler_steps,
            "recoveries": self.recoveries,
        }
