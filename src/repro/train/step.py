"""The jit'd train / serve step builders (shared by trainer, dryrun, bench).

``build_train_step`` returns a donated, fully-sharded
``(params, opt_state, [err_state], batch) -> (params, opt_state, metrics)``.
Microbatching (gradient accumulation) is a lax.scan over batch splits;
gradient compression (int8 + error feedback) is optional.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adamw as opt
from repro.optim import compression as comp


def build_train_step(
    model,
    ocfg: opt.AdamWConfig,
    *,
    accum_steps: int = 1,
    grad_compression: bool = False,
):
    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    def train_step(params, opt_state, batch, err_state=None):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                B = x.shape[0]
                return x.reshape((accum_steps, B // accum_steps) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (
                    acc[0] + l / accum_steps,
                    jax.tree.map(lambda a, b: a + b / accum_steps, acc[1], g),
                ), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), micro
            )
        if grad_compression:
            grads, err_state = comp.compress_grads(grads, err_state)
        params, opt_state, metrics = opt.apply_updates(params, grads, opt_state, ocfg)
        metrics["loss"] = loss
        if grad_compression:
            return params, opt_state, err_state, metrics
        return params, opt_state, metrics

    return train_step


def build_eval_step(model):
    def eval_step(params, batch):
        return model.train_loss(params, batch)

    return eval_step
