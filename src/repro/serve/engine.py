"""Continuous-batching serving engine over the PUMA paged KV pool.

Lifecycle per step:

  1. **admit** — pull queued requests while pool blocks + seq slots allow;
     PUMA placement (worst-fit first allocation) assigns prompt blocks.
     Admission scans a bounded *lookahead window* of the queue, so one
     large head-of-line request cannot starve small requests behind it.
  2. **prefill** — teacher-forced pass with a dense scratch cache, then the
     per-layer K/V pages are scattered into the pool blocks (a bulk
     RowClone-style block write).
  3. **decode** — one fused step for every live sequence via
     ``paged_decode_step`` (block tables + seq_lens), greedy sampling.
  4. **bookkeeping** — new-token K/V written to the PUMA-chosen block
     (``extend`` keeps arena locality), finished sequences release blocks.

Hardened (degraded-mode) path — no request is ever silently dropped:

  * ``submit`` rejects *never-admissible* requests (empty prompt, or
    prompt+max_new exceeding the per-sequence block ceiling) with a typed
    :class:`~repro.robustness.RequestRejected` — instead of queueing work
    that can never run.
  * A request may carry ``deadline_steps``; once ``clock`` passes it the
    request is cancelled with :class:`~repro.robustness.DeadlineExceeded`
    and its blocks are released (cooperative cancellation).
  * When a decode-time block ``extend`` fails (pool pressure or an injected
    fault), the engine preempts the *youngest* live sequence — the one
    whose blocks were allocated most recently, i.e. LRU over block
    allocation time and the cheapest prefill to redo — releasing its blocks
    and re-queueing it at the queue front.  On re-admission the preempted
    request *recomputes* its KV from ``prompt + out[:-1]`` (recompute-on-
    resume), so generation continues bit-exactly.
  * If the engine sits with an empty batch and a non-empty queue for more
    than ``stall_patience`` steps, the stuck requests are rejected with a
    stall report attached — loud failure instead of a silent busy-loop.

Metrics surface the paper's figure of merit: block-table contiguity (the
"% executable in PUD" analogue) plus throughput and degraded-mode counters
(rejected / cancelled / preemptions).  With ``KVPoolConfig.n_channels > 1``
the pool stripes each request's blocks round-robin across memory channels,
and ``metrics()``/``channel_occupancy()`` additionally report per-channel
block occupancy and its load balance.

Open-loop load support (:mod:`repro.serve.loadgen` is the consumer):
``cancel(rid)`` is client-side early cancellation, ``step_hooks`` receive a
:meth:`ServeEngine.step_sample` after every step, and ``run_for`` /
``drain`` slice engine time so a traffic driver can interleave arrivals
with bounded stepping instead of handing over the whole loop.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import TYPE_CHECKING, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kv_pool import KVPoolConfig, PagedKVPool
from repro.robustness import (
    ClientCancelled,
    DeadlineExceeded,
    EngineStalled,
    RequestRejected,
)
from repro.serve.paged_runner import paged_decode_step, paged_decode_step_jit

if TYPE_CHECKING:
    from repro.robustness.faults import FaultInjector


@dataclasses.dataclass(frozen=True)
class MaintenanceConfig:
    """Watermarks for the background compaction hook in :meth:`ServeEngine.step`.

    A pass triggers when the pool's free-tile fraction falls below
    ``free_low`` *or* its fragmentation rises above ``frag_high`` *or* the
    live block tables' mean contiguous-run fraction falls below
    ``contig_low`` — but at most once every ``every`` engine clock ticks, so
    maintenance cannot monopolise the step loop.  ``max_moves`` bounds one
    pass; the pass cost (RowClone rows + host copies, see
    :func:`repro.core.pud.price_migration`) lands in the engine's
    ``maintenance_ns`` counter, competing with live traffic in the cost
    model.
    """

    free_low: float = 0.25
    frag_high: float = 0.5
    contig_low: float = 0.85
    max_moves: int = 32
    every: int = 4


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    # robustness / QoS fields
    deadline_steps: Optional[int] = None   # engine-clock budget from submit
    status: str = "queued"                 # queued|running|done|rejected|cancelled
    submit_clock: int = 0
    admit_clock: int = -1
    finish_clock: int = -1                 # clock at done/rejected/cancelled
    tenant: Optional[str] = None           # traffic class (loadgen bookkeeping)
    preemptions: int = 0
    error: Optional[Exception] = None

    def ctx_tokens(self) -> int:
        """Tokens whose KV must exist before the next decode step — the
        prompt plus all-but-the-last generated token (the last one is the
        next decode *input*).  This is what a resume-after-preemption
        prefill recomputes."""
        return len(self.prompt) + max(0, len(self.out) - 1)


class ServeEngine:
    def __init__(
        self,
        model,
        params,
        pool_cfg: KVPoolConfig,
        *,
        use_kernel: bool = False,   # pallas-interpret is slow on CPU; jnp ref default
        jit: bool = True,           # compile prefill/decode per shape (load-
                                    # harness scale needs it; False = eager)
        eos_id: Optional[int] = None,
        injector: Optional["FaultInjector"] = None,
        admission_lookahead: int = 8,
        stall_patience: int = 3,
        maintenance: Optional[MaintenanceConfig] = None,
        trace=None,
    ):
        cfg = model.cfg
        assert pool_cfg.kv_heads == cfg.n_kv_heads and pool_cfg.head_dim == cfg.hd
        assert pool_cfg.n_layers == cfg.n_layers
        self.model = model
        self.cfg = cfg
        self.params = params
        self.pool = PagedKVPool(pool_cfg, injector=injector)
        self.use_kernel = use_kernel
        self.jit = jit
        if jit:
            # cache the jitted prefill step ON the model so every engine
            # over the same model shares one XLA cache (scenario reruns
            # compile nothing); the paged step's shared wrapper lives in
            # paged_runner for the same reason.
            fn = getattr(model, "_jit_decode_step", None)
            if fn is None:
                fn = jax.jit(model.decode_step)
                model._jit_decode_step = fn
            self._decode_step = fn
            self._paged_step = paged_decode_step_jit
        else:
            self._decode_step = model.decode_step
            self._paged_step = paged_decode_step
        self.eos_id = eos_id
        self.admission_lookahead = max(1, admission_lookahead)
        self.stall_patience = max(1, stall_patience)
        self.queue: Deque[Request] = deque()
        self.live: Dict[int, Request] = {}     # slot -> request
        self.done: List[Request] = []
        self.rejected: List[Request] = []
        self.cancelled: List[Request] = []
        self.steps = 0                          # decode steps (batch advanced)
        self.clock = 0                          # every step() call, incl. stalls
        self.tokens_decoded = 0
        self.tokens_prefilled = 0               # teacher-forced KV-fill tokens
        self.preemptions = 0
        self.submitted = 0
        self._stall_steps = 0
        #: step-level metric hooks: each callable gets ``(engine, sample)``
        #: after every :meth:`step`, where ``sample`` is :meth:`step_sample`.
        #: The load harness registers its occupancy/queue-depth sampler here.
        self.step_hooks: List = []
        # background maintenance (watermark-triggered compaction)
        self.maintenance = maintenance
        self.maintenance_ns = 0.0
        self.compaction_passes = 0
        self.blocks_migrated = 0
        self._last_maintenance = -(10 ** 9)
        #: tracegen recorder (:class:`repro.trace.record.TraceRecorder`):
        #: shared with the pool so request lifecycle, prompt-KV fills,
        #: decode-token writes, and compaction all land in one trace.
        self.trace = trace
        self.pool.trace = trace
        self._step_writes: List = []   # (slot, block) token writes this step

    # -- submission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request; raises :class:`RequestRejected` immediately if it
        can *never* be admitted (so no work is silently parked forever)."""
        self.submitted += 1
        req.submit_clock = self.clock
        total_blocks = self.pool.blocks_for(len(req.prompt) + req.max_new)
        if not req.prompt:
            err = RequestRejected("empty prompt", rid=req.rid)
        elif total_blocks > self.pool.capacity_blocks:
            err = RequestRejected(
                "request can never be admitted: prompt+max_new exceeds the "
                "per-sequence block ceiling",
                rid=req.rid, blocks_needed=total_blocks,
                capacity_blocks=self.pool.capacity_blocks,
            )
        else:
            self.queue.append(req)
            return
        req.status = "rejected"
        req.error = err
        req.finish_clock = self.clock
        self.rejected.append(req)
        raise err

    def cancel(self, rid: int) -> bool:
        """Client-side early cancellation: drop ``rid`` from the queue or the
        live batch (releasing its KV blocks).  Returns False when the request
        is not in flight (already done / rejected / cancelled / unknown) —
        cancelling twice is a harmless no-op, like closing a dead socket."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                self._cancel(req, ClientCancelled(
                    "cancelled by client while queued", rid=rid,
                    waited=self.clock - req.submit_clock,
                ))
                return True
        for slot, req in list(self.live.items()):
            if req.rid == rid:
                del self.live[slot]
                self.pool.release(slot)
                req.slot = None
                self._cancel(req, ClientCancelled(
                    "cancelled by client mid-decode", rid=rid,
                    decoded=len(req.out),
                ))
                return True
        return False

    # -- degraded-mode bookkeeping --------------------------------------------
    def _reject(self, req: Request, err: RequestRejected) -> None:
        req.status = "rejected"
        req.error = err
        req.finish_clock = self.clock
        self.rejected.append(req)

    def _cancel(self, req: Request, err: Exception) -> None:
        req.status = "cancelled"
        req.error = err
        req.finish_clock = self.clock
        self.cancelled.append(req)

    def _sweep_deadlines(self) -> None:
        now = self.clock
        for i in range(len(self.queue) - 1, -1, -1):
            req = self.queue[i]
            if req.deadline_steps is not None and now - req.submit_clock > req.deadline_steps:
                del self.queue[i]
                self._cancel(req, DeadlineExceeded(
                    "deadline expired while queued",
                    rid=req.rid, deadline_steps=req.deadline_steps,
                    waited=now - req.submit_clock,
                ))
        expired = [
            s for s, r in self.live.items()
            if r.deadline_steps is not None and now - r.submit_clock > r.deadline_steps
        ]
        for slot in expired:
            req = self.live.pop(slot)
            self.pool.release(slot)
            req.slot = None
            self._cancel(req, DeadlineExceeded(
                "deadline expired mid-decode",
                rid=req.rid, deadline_steps=req.deadline_steps,
                decoded=len(req.out),
            ))

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Preemption victim: the youngest live sequence (blocks allocated
        most recently — LRU over allocation time, cheapest to recompute)."""
        candidates = [s for s in self.live if s != exclude]
        if not candidates:
            return None
        return max(candidates, key=lambda s: (self.live[s].admit_clock, s))

    def _preempt(self, slot: int) -> None:
        req = self.live.pop(slot)
        self.pool.release(slot)
        req.slot = None
        req.status = "queued"
        req.preemptions += 1
        self.preemptions += 1
        self.queue.appendleft(req)   # resume first: it already holds progress

    def _append_with_recovery(self, slot: int, *, allow_preempt: bool = True) -> bool:
        """`append_token` with transient-fault retries and preemption.

        Transient injected misses are retried (fresh fault draw each time);
        true exhaustion preempts the youngest *other* sequence and retries.
        Returns False only when the pool genuinely cannot host one more
        block for this sequence.

        ``allow_preempt=False`` is the admission-time mode: a sequence that
        is only being *prefilled* must never evict sequences holding decode
        progress — two near-full requests would otherwise evict each other
        forever inside one step (admit A, A's growth block preempts B, B
        lands back at the queue head, B is admitted and preempts A, ...).
        """
        for _ in range(3):
            if self.pool.append_token(slot):
                return True
            if self.pool.pool.free_tiles() > 0:
                continue                      # injected transient miss
            if not allow_preempt:
                return False
            victim = self._pick_victim(exclude=slot)
            if victim is None:
                return False
            self._preempt(victim)
        return self.pool.append_token(slot)

    # -- background maintenance ------------------------------------------------
    def _maybe_maintain(self) -> None:
        """Run one compaction pass when a watermark trips (rate-limited)."""
        mc = self.maintenance
        if mc is None or self.clock - self._last_maintenance < mc.every:
            return
        pool = self.pool.pool
        total = pool.total_tiles
        free_frac = pool.free_tiles() / total if total else 1.0
        frag = pool.fragmentation()
        contig = self.pool.contiguity_report()["mean_contiguous_fraction"]
        if free_frac > mc.free_low and frag < mc.frag_high and contig > mc.contig_low:
            return
        self._last_maintenance = self.clock
        report = self.pool.compact(
            max_moves=mc.max_moves, use_kernel=self.use_kernel
        )
        if report is not None and report.executed:
            self.compaction_passes += 1
            self.blocks_migrated += report.executed
            self.maintenance_ns += report.total_ns

    # -- prefill --------------------------------------------------------------
    def _prefill(self, req: Request) -> bool:
        """Teacher-forced KV fill over ``prompt + out[:-1]`` — identical for
        a fresh request (out empty) and a preempted one resuming
        (recompute-on-resume).  Returns False if the request had to be
        rejected (pathological: pool cannot host the sampled token)."""
        cfg = self.cfg
        ctx = req.prompt + req.out[:-1]
        toks = jnp.asarray([ctx], jnp.int32)
        S = toks.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)[None]
        cache = self.model.init_cache(1, S, recent_size=S)
        batch = {"tokens": toks, "positions": pos}
        logits, cache = self._decode_step(self.params, batch, cache)
        self.tokens_prefilled += S
        # prompt KV lands in the recent ring (split cache, len_main == 0)
        k, v = cache["layers"]["recent"]            # (L, 1, S, KV, hd)
        for li in range(cfg.n_layers):
            self.pool.write_prompt_kv(req.slot, li, k[li, 0, :S], v[li, 0, :S])
        if self.trace is not None:
            self.trace.on_prefill(
                req.slot, req.rid, S, self.pool.tiles_of(req.slot)
            )
        if not req.out:
            req.out.append(int(jnp.argmax(logits[0])))
        # account the pending token: it becomes the next decode input.
        # allow_preempt=False — admission must never evict decode progress
        # (see _append_with_recovery); the admission gate below makes this
        # failure genuinely pathological (faults / per-seq block ceiling).
        if not self._append_with_recovery(req.slot, allow_preempt=False):
            slot = req.slot
            self.pool.release(slot)
            del self.live[slot]
            req.slot = None
            self._reject(req, RequestRejected(
                "KV pool cannot host the sampled token", rid=req.rid,
            ))
            return False
        return True

    # -- one engine step ---------------------------------------------------------
    def step(self) -> bool:
        """Admit + decode one token for all live seqs. False when idle.

        After the step, every registered ``step_hooks`` callable receives
        ``(engine, step_sample())`` — the open-loop load harness samples
        occupancy / queue depth / degraded-mode counters this way without
        the engine knowing about any particular consumer.

        The sample is taken once, *after* the step (and any watermark
        compaction inside it) completes, and each hook gets its own
        snapshot copy: a consumer that mutates its sample — or registers /
        removes hooks from inside one — cannot leak an inconsistent view
        into the other consumers mid-iteration."""
        if self.trace is not None:
            self._step_writes = []
            d0 = self.tokens_decoded
        alive = self._step()
        if self.trace is not None:
            self.trace.on_step(
                self.clock, self.tokens_decoded - d0, self._step_writes
            )
        if self.step_hooks:
            sample = self.step_sample()
            for hook in list(self.step_hooks):
                hook(self, dict(sample))
        return alive

    def _step(self) -> bool:
        self.clock += 1
        self._sweep_deadlines()

        # 1) admit — bounded lookahead so a large head request cannot starve
        #    admissible smaller requests behind it (HOL-blocking fix)
        idx = 0
        scanned = 0
        while idx < len(self.queue) and scanned < self.admission_lookahead:
            req = self.queue[idx]
            slot = self.pool.admit(req.ctx_tokens())
            if slot is None:
                idx += 1
                scanned += 1
                continue
            # prefill appends the sampled token immediately: if that needs a
            # growth block the pool doesn't have, admitting now would either
            # reject the request or evict running work — leave it queued.
            if (self.pool.pool.free_tiles() == 0
                    and self.pool.blocks_for(req.ctx_tokens() + 1)
                    > self.pool.blocks_for(req.ctx_tokens())):
                self.pool.release(slot)
                idx += 1
                scanned += 1
                continue
            del self.queue[idx]
            req.slot = slot
            req.status = "running"
            req.admit_clock = self.clock
            self.live[slot] = req
            self._prefill(req)

        if not self.live:
            if not self.queue:
                return False
            # empty batch, non-empty queue: a stall.  Tolerate a few steps
            # (transient injected faults resolve), then fail loudly.
            self._stall_steps += 1
            if self._stall_steps > self.stall_patience:
                report = self.stall_report()
                while self.queue:
                    req = self.queue.popleft()
                    self._reject(req, RequestRejected(
                        "engine stalled: request not admissible with an idle pool",
                        rid=req.rid,
                        blocks_needed=self.pool.blocks_for(req.ctx_tokens()),
                        report=report,
                    ))
                self._stall_steps = 0
                return False
            # stalled admission is exactly when defrag helps most
            self._maybe_maintain()
            return True
        self._stall_steps = 0

        # 2) fused decode for all live sequences
        slots = sorted(self.live)
        cfg = self.cfg
        tbl_full = self.pool.block_table()
        lens_full = self.pool.seq_lens()
        tokens = np.array([[self.live[s].out[-1]] for s in slots], np.int32)
        positions = np.array([[lens_full[s] - 1] for s in slots], np.int32)
        tbl = jnp.asarray(tbl_full[slots])
        lens = jnp.asarray(lens_full[slots])

        logits, new_k, new_v = self._paged_step(
            self.params, cfg,
            jnp.asarray(tokens), jnp.asarray(positions),
            self.pool.k, self.pool.v, tbl, lens,
            use_kernel=self.use_kernel,
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))

        # 3) write current-token KV into PUMA-placed blocks, advance seqs
        for bi, slot in enumerate(slots):
            if slot not in self.live:
                continue                    # preempted earlier this loop
            req = self.live[slot]
            for li in range(cfg.n_layers):
                self.pool.write_token_kv(slot, li, new_k[li, bi], new_v[li, bi])
            if self.trace is not None:
                # one block-granular write per decoded token (all layers'
                # planes of that block count as the one row touch)
                self._step_writes.append(
                    (slot, self.pool.block_of_token(slot))
                )
            tok = int(nxt[bi])
            self.tokens_decoded += 1
            finished = (
                len(req.out) + 1 >= req.max_new
                or (self.eos_id is not None and tok == self.eos_id)
            )
            req.out.append(tok)
            if finished:
                self.pool.release(slot)
                del self.live[slot]
                req.slot = None
                req.status = "done"
                req.finish_clock = self.clock
                self.done.append(req)
            elif not self._append_with_recovery(slot):
                self.pool.release(slot)
                del self.live[slot]
                req.slot = None
                self._reject(req, RequestRejected(
                    "KV pool cannot host the next token", rid=req.rid,
                    decoded=len(req.out),
                ))
        self.steps += 1
        self._maybe_maintain()
        return bool(self.live or self.queue)

    def drain(self, max_steps: int = 10_000) -> List[Request]:
        """Step until idle without raising — the open-loop load harness ends
        a scenario with this (rejections/cancellations stay recorded in the
        ledger rather than aborting the run)."""
        for _ in range(max_steps):
            if not self.step():
                break
        return self.done

    def run_for(self, n_steps: int) -> bool:
        """Time-sliced run: advance at most ``n_steps`` engine ticks.

        Returns the last ``step()`` result (False = engine went idle), so an
        open-loop driver can interleave arrival submission with bounded
        slices of engine time instead of handing over the whole loop."""
        alive = True
        for _ in range(max(0, n_steps)):
            alive = self.step()
            if not alive:
                break
        return alive

    def run(self, max_steps: int = 10_000, raise_on_error: bool = True) -> List[Request]:
        self.drain(max_steps)
        if raise_on_error:
            if self.queue or self.live:
                raise EngineStalled(
                    "serving loop ended with unfinished work",
                    report=self.stall_report(),
                )
            for r in self.rejected:
                if r.error is not None:
                    raise r.error
        return self.done

    # -- introspection --------------------------------------------------------
    def stall_report(self) -> Dict[str, object]:
        """Snapshot of why the engine is (or was) unable to make progress."""
        return {
            "clock": self.clock,
            "steps": self.steps,
            "queued": [
                {"rid": r.rid, "blocks_needed": self.pool.blocks_for(r.ctx_tokens()),
                 "preemptions": r.preemptions}
                for r in self.queue
            ],
            "live": len(self.live),
            "free_tiles": self.pool.pool.free_tiles(),
            "total_tiles": self.pool.pool.total_tiles,
            "free_slots": len(self.pool._free_slots),
            "done": len(self.done),
            "rejected": len(self.rejected),
            "cancelled": len(self.cancelled),
            "preemptions": self.preemptions,
        }

    def step_sample(self) -> Dict[str, float]:
        """One step-granular metric sample (what ``step_hooks`` receive):
        queue/batch depth, pool occupancy, live block-table contiguity (the
        paper's PUD-executable-fraction analogue — meaningful only while
        sequences are live, hence sampled here rather than post-drain), and
        the degraded-mode counters.  All floats."""
        occ = self.pool.occupancy()
        rep = self.pool.contiguity_report()
        return {
            "contiguity": rep["mean_contiguous_fraction"],
            "descriptors_per_tile": rep["descriptors_per_tile"],
            "channel_balance": rep["channel_balance"],
            "clock": float(self.clock),
            "steps": float(self.steps),
            "live": float(len(self.live)),
            "queued": float(len(self.queue)),
            "free_tiles": occ["free_tiles"],
            "used_fraction": occ["used_fraction"],
            "tokens_decoded": float(self.tokens_decoded),
            "tokens_prefilled": float(self.tokens_prefilled),
            "done": float(len(self.done)),
            "rejected": float(len(self.rejected)),
            "cancelled": float(len(self.cancelled)),
            "preemptions": float(self.preemptions),
        }

    def metrics(self) -> Dict[str, float]:
        rep = self.pool.contiguity_report()
        rep.update(
            clock=float(self.clock),
            steps=float(self.steps),
            tokens=float(self.tokens_decoded),
            tokens_prefilled=float(self.tokens_prefilled),
            submitted=float(self.submitted),
            done=float(len(self.done)),
            queue_depth=float(len(self.queue)),
            used_fraction=self.pool.occupancy()["used_fraction"],
            frag=self.pool.pool.fragmentation(),
            align_hits=float(self.pool.pool.stats.align_hits),
            align_misses=float(self.pool.pool.stats.align_misses),
            rejected=float(len(self.rejected)),
            cancelled=float(len(self.cancelled)),
            preemptions=float(self.preemptions),
            injected_misses=float(self.pool.pool.stats.injected_misses),
            maintenance_ns=float(self.maintenance_ns),
            compaction_passes=float(self.compaction_passes),
            blocks_migrated=float(self.blocks_migrated),
        )
        return rep

    def channel_occupancy(self) -> Dict[str, object]:
        """Per-channel block occupancy of the paged KV pool."""
        return self.pool.channel_occupancy()
