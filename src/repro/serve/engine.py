"""Continuous-batching serving engine over the PUMA paged KV pool.

Lifecycle per step:

  1. **admit** — pull queued requests while pool blocks + seq slots allow;
     PUMA placement (worst-fit first allocation) assigns prompt blocks.
  2. **prefill** — teacher-forced pass with a dense scratch cache, then the
     per-layer K/V pages are scattered into the pool blocks (a bulk
     RowClone-style block write).
  3. **decode** — one fused step for every live sequence via
     ``paged_decode_step`` (block tables + seq_lens), greedy sampling.
  4. **bookkeeping** — new-token K/V written to the PUMA-chosen block
     (``extend`` keeps arena locality), finished sequences release blocks.

Metrics surface the paper's figure of merit: block-table contiguity (the
"% executable in PUD" analogue) plus throughput counters.  With
``KVPoolConfig.n_channels > 1`` the pool stripes each request's blocks
round-robin across memory channels (contiguous per-channel chunks), and
``metrics()``/``channel_occupancy()`` additionally report the per-channel
block occupancy and its load balance — the serving-side view of the
channel-parallel PUD substrate in :mod:`repro.core.controller`.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kv_pool import KVPoolConfig, PagedKVPool
from repro.serve.paged_runner import paged_decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None


class ServeEngine:
    def __init__(
        self,
        model,
        params,
        pool_cfg: KVPoolConfig,
        *,
        use_kernel: bool = False,   # pallas-interpret is slow on CPU; jnp ref default
        eos_id: Optional[int] = None,
    ):
        cfg = model.cfg
        assert pool_cfg.kv_heads == cfg.n_kv_heads and pool_cfg.head_dim == cfg.hd
        assert pool_cfg.n_layers == cfg.n_layers
        self.model = model
        self.cfg = cfg
        self.params = params
        self.pool = PagedKVPool(pool_cfg)
        self.use_kernel = use_kernel
        self.eos_id = eos_id
        self.queue: Deque[Request] = deque()
        self.live: Dict[int, Request] = {}     # slot -> request
        self.done: List[Request] = []
        self.steps = 0
        self.tokens_decoded = 0

    # -- submission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- prefill --------------------------------------------------------------
    def _prefill(self, req: Request) -> None:
        cfg = self.cfg
        toks = jnp.asarray([req.prompt], jnp.int32)
        S = toks.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)[None]
        cache = self.model.init_cache(1, S, recent_size=S)
        batch = {"tokens": toks, "positions": pos}
        logits, cache = self.model.decode_step(self.params, batch, cache)
        # prompt KV lands in the recent ring (split cache, len_main == 0)
        k, v = cache["layers"]["recent"]            # (L, 1, S, KV, hd)
        for li in range(cfg.n_layers):
            self.pool.write_prompt_kv(req.slot, li, k[li, 0, :S], v[li, 0, :S])
        first = int(jnp.argmax(logits[0]))
        req.out.append(first)
        # account the sampled token: it becomes the next decode input
        self.pool.append_token(req.slot)

    # -- one engine step ---------------------------------------------------------
    def step(self) -> bool:
        """Admit + decode one token for all live seqs. False when idle."""
        # 1) admit
        while self.queue:
            req = self.queue[0]
            slot = self.pool.admit(len(req.prompt))
            if slot is None:
                break
            self.queue.popleft()
            req.slot = slot
            self.live[slot] = req
            self._prefill(req)

        if not self.live:
            return False

        # 2) fused decode for all live sequences
        slots = sorted(self.live)
        B = len(slots)
        cfg = self.cfg
        tbl_full = self.pool.block_table()
        lens_full = self.pool.seq_lens()
        tokens = np.array([[self.live[s].out[-1]] for s in slots], np.int32)
        positions = np.array([[lens_full[s] - 1] for s in slots], np.int32)
        tbl = jnp.asarray(tbl_full[slots])
        lens = jnp.asarray(lens_full[slots])

        logits, new_k, new_v = paged_decode_step(
            self.params, cfg,
            jnp.asarray(tokens), jnp.asarray(positions),
            self.pool.k, self.pool.v, tbl, lens,
            use_kernel=self.use_kernel,
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))

        # 3) write current-token KV into PUMA-placed blocks, advance seqs
        for bi, slot in enumerate(slots):
            req = self.live[slot]
            for li in range(cfg.n_layers):
                self.pool.write_token_kv(slot, li, new_k[li, bi], new_v[li, bi])
            tok = int(nxt[bi])
            self.tokens_decoded += 1
            finished = (
                len(req.out) + 1 >= req.max_new
                or (self.eos_id is not None and tok == self.eos_id)
            )
            if finished:
                req.out.append(tok)
                self.pool.release(slot)
                del self.live[slot]
                self.done.append(req)
            else:
                req.out.append(tok)
                self.pool.append_token(slot)
        self.steps += 1
        return bool(self.live or self.queue)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if not self.step():
                break
        return self.done

    def metrics(self) -> Dict[str, float]:
        rep = self.pool.contiguity_report()
        rep.update(
            steps=float(self.steps),
            tokens=float(self.tokens_decoded),
            frag=self.pool.pool.fragmentation(),
            align_hits=float(self.pool.pool.stats.align_hits),
            align_misses=float(self.pool.pool.stats.align_misses),
        )
        return rep

    def channel_occupancy(self) -> Dict[str, object]:
        """Per-channel block occupancy of the paged KV pool."""
        return self.pool.channel_occupancy()
