"""Decode-step over the PUMA paged KV pool (dense/moe/vlm families).

This is where the paper's technique meets the serving path: attention reads
KV through the *block table* (re-mmap analogue) with the
``repro.kernels.paged_attention`` kernel, and the new token's K/V is written
back into pool blocks placed by the PUMA policy.

The runner mirrors ``LM.decode_step`` exactly (same params, same math) with
the dense cache swapped for (k_pool, v_pool, block_table, seq_lens); layer
loop is unrolled (serving configs are small; the dry-run path uses the
scanned dense-cache step).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import ops as paged_ops
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.rope import apply_rope

__all__ = ["paged_decode_step", "paged_decode_step_jit"]


def paged_decode_step(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,        # (B, 1)
    positions: jax.Array,     # (B, 1)
    k_pool: jax.Array,        # (L, nb, bs, KV, hd)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, max_blocks)
    seq_lens: jax.Array,      # (B,) length INCLUDING the current token
    *,
    use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (logits (B, V), new_k (L, B, KV, hd), new_v (L, B, KV, hd)).

    The caller scatters new_k/new_v into pool blocks (host-side PUMA
    bookkeeping decides *which* blocks — that's the paper's policy layer).
    Attention masks to ``seq_lens`` which already counts the current token,
    whose K/V is injected via a one-slot overlay so the kernel sees it
    before the host writes it back.
    """
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dtype = jnp.dtype(cfg.dtype)

    x = L.embed_tokens(params["embed"], tokens, dtype)   # (B, 1, d)

    new_ks, new_vs = [], []
    n_layers = cfg.n_layers
    for li in range(n_layers):
        lp = jax.tree.map(lambda a: a[li], params["layers"])
        h = L.apply_norm(lp["ln1"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"].astype(dtype))
        k1 = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"].astype(dtype))
        v1 = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"].astype(dtype))
        q = apply_rope(cfg, q, positions)
        k1 = apply_rope(cfg, k1, positions)

        # overlay: extend each sequence's KV stream with the current token by
        # appending a virtual block holding it at position seq_len-1.
        attn_out = _paged_attention_with_current(
            q[:, 0], k_pool[li], v_pool[li], block_tables, seq_lens,
            k1[:, 0].astype(k_pool.dtype), v1[:, 0].astype(v_pool.dtype),
            use_kernel=use_kernel,
        )
        a = jnp.einsum("bhk,hkd->bd", attn_out, lp["attn"]["wo"].astype(dtype))
        x = x + a[:, None]
        h = L.apply_norm(lp["ln2"], x)
        if cfg.n_experts:
            m, _ = MOE.apply_moe(lp["moe"], cfg, h)
        else:
            m = L.apply_mlp(lp["mlp"], h)
        x = x + m
        new_ks.append(k1[:, 0])
        new_vs.append(v1[:, 0])

    x = L.apply_norm(params["final_ln"], x)
    logits = L.logits_from(params["embed"], x)[:, 0]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def _paged_attention_with_current(
    q, k_pool, v_pool, block_tables, seq_lens, k_cur, v_cur, *, use_kernel
):
    """Attention over pooled KV plus the in-flight token.

    We append one per-sequence "current" block to the pool view and extend
    each block table with its index; masking is handled by seq_lens.  The
    current token sits at position ceil: we place it in a dedicated block at
    offset (seq_len-1) % block_size of a scratch block filled at that slot.
    For simplicity and exactness, scratch blocks hold ONLY the current token
    at slot 0 and the table entry is appended with an adjusted... — instead
    we take the simpler exact route: compute attention over pool (lengths
    seq_len-1) and merge the current token analytically.
    """
    B, H, hd = q.shape
    KV = k_pool.shape[2]
    scale = hd ** -0.5
    group = H // KV

    # past contribution (lengths exclude the current token)
    past_len = seq_lens - 1
    out_past = paged_ops.paged_attention(
        q, k_pool, v_pool, block_tables, past_len,
        scale=scale, use_kernel=use_kernel,
    )                                                     # (B, H, hd)

    # merge current token: softmax over [past, current] decomposes into
    # weighted average of past attention output and v_cur.
    qg = q.reshape(B, KV, group, hd).astype(jnp.float32)
    s_cur = jnp.einsum("bkgd,bkd->bkg", qg, k_cur.astype(jnp.float32)) * scale

    # recompute the past logsumexp (cheap second pass over logits only)
    lse_past = _paged_lse(q, k_pool, block_tables, past_len, scale)  # (B,KV,group)
    has_past = (past_len > 0)[:, None, None]
    m = jnp.maximum(jnp.where(has_past, lse_past, -jnp.inf), s_cur)
    w_past = jnp.where(has_past, jnp.exp(lse_past - m), 0.0)
    w_cur = jnp.exp(s_cur - m)
    denom = w_past + w_cur
    out = (
        out_past.reshape(B, KV, group, hd).astype(jnp.float32) * w_past[..., None]
        + v_cur.astype(jnp.float32)[:, :, None, :] * w_cur[..., None]
    ) / denom[..., None]
    return out.reshape(B, H, hd).astype(q.dtype)


def _paged_lse(q, k_pool, block_tables, seq_lens, scale):
    """log-sum-exp of past attention logits, via the jnp gather path."""
    B, H, hd = q.shape
    nb, bs, KV, _ = k_pool.shape
    group = H // KV
    idx = jnp.maximum(block_tables, 0)
    k = k_pool[idx].reshape(B, -1, KV, hd)                 # (B, S, KV, hd)
    qg = q.reshape(B, KV, group, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    pos = jnp.arange(s.shape[-1])[None, None, None, :]
    s = jnp.where(pos < seq_lens[:, None, None, None], s, -jnp.inf)
    return jax.nn.logsumexp(s, axis=-1)                    # (B, KV, group)


#: process-wide jitted variant (cfg and use_kernel are static): the serving
#: engine's decode hot path.  One shared wrapper — not one per engine — so
#: the XLA cache survives across scenario/engine instances and a load run
#: compiles each (batch, pool) shape exactly once.
paged_decode_step_jit = jax.jit(
    paged_decode_step, static_argnums=(1,), static_argnames=("use_kernel",)
)
