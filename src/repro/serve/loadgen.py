"""Deterministic serving-traffic generation + open-loop scenario runner.

PUMA's figure of merit — the fraction of work executable in-DRAM under
allocator-controlled placement — has to hold up under *realistic serving
traffic*, not just synthetic churn (PiDRAM's lesson: end-to-end PuM claims
are only as good as the workloads that drive them).  This module is the
workload side of that argument:

* **Arrival processes** (:class:`ArrivalSpec`) — steady (fixed-rate),
  Poisson (exponential inter-arrival), and bursty (geometric-gap request
  clusters), all seeded and integer-stepped so a fixed seed reproduces the
  exact same request stream byte-for-byte.
* **Traffic classes** (:class:`TenantSpec`) — per-tenant prompt/decode
  length distributions, deadlines, and early-cancellation rates.
  :func:`tenant_from_arch` derives a tenant's shape deterministically from
  a config-registry architecture (bigger models → longer prompts/decodes),
  so multi-tenant mixes are "drawn from the registry" rather than invented
  per-benchmark.  Prompt lengths come from small *discrete bucket sets*:
  every distinct prefill length is a fresh XLA trace, so bounded buckets
  keep thousand-request scenarios tractable on the CPU smoke model.
* **Scenarios** (:class:`Scenario`, :func:`build_scenario`) — the named,
  fixed-seed scenario registry the serving benchmark and CI gate share:
  ``steady``, ``bursty``, ``long_context``, ``multi_tenant``,
  ``cancel_heavy``.
* **Open-loop runner** (:func:`play`) — submits each request at its
  arrival tick (arrivals do not wait for the engine — open-loop, so queue
  delay is *measured*, not hidden), fires client cancellations on
  schedule, samples occupancy/queue depth per step through
  ``ServeEngine.step_hooks``, drains, and folds everything into one
  JSON-friendly metrics record (:func:`summarize`).

Throughput is reported against the deterministic :class:`SimCost` serving-
time model (wall clock is not reproducible; the benchmark gate wants
byte-identical reruns).  Wall-clock numbers stay on stdout only.

Conservation contract (the property tests' anchor): after a drained run,
``submitted == done + rejected + cancelled`` — the engine never silently
drops a generated request, whatever the scenario does to it.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.robustness import RequestRejected
from repro.serve.engine import Request, ServeEngine

__all__ = [
    "ArrivalSpec",
    "TenantSpec",
    "RequestSpec",
    "Scenario",
    "SimCost",
    "SCENARIO_NAMES",
    "tenant_from_arch",
    "build_scenario",
    "play",
    "summarize",
]


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """When requests show up, in engine-clock ticks.

    ``rate`` is mean requests per tick for ``steady``/``poisson``;
    ``bursty`` emits clusters of ``burst_size`` at one tick separated by
    ~``burst_gap``-tick geometric gaps (an on/off source: idle, then a
    thundering herd — the worst case for admission and pool pressure).
    """

    kind: str = "steady"            # steady | poisson | bursty
    rate: float = 0.5
    burst_size: int = 8
    burst_gap: float = 24.0

    def arrivals(self, rng: np.random.Generator, n: int) -> List[int]:
        """``n`` non-decreasing integer arrival ticks (deterministic in
        ``rng`` state — callers pass a freshly seeded generator)."""
        if n <= 0:
            return []
        if self.kind == "steady":
            return [int(i / self.rate) for i in range(n)]
        if self.kind == "poisson":
            gaps = rng.exponential(1.0 / self.rate, size=n)
            return [int(t) for t in np.floor(np.cumsum(gaps))]
        if self.kind == "bursty":
            out: List[int] = []
            t = 0
            while len(out) < n:
                out.extend([t] * min(self.burst_size, n - len(out)))
                t += 1 + int(rng.exponential(self.burst_gap))
            return out
        raise ValueError(f"unknown arrival kind {self.kind!r}")


# ---------------------------------------------------------------------------
# traffic classes (tenants)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One traffic class: who is sending and what their requests look like.

    ``prompt_lens``/``max_new_lens`` are discrete bucket sets sampled
    uniformly (bounded XLA shape variety — see module docstring).
    ``cancel_rate`` is the probability a request is withdrawn by the client
    ``cancel_window`` ticks after submission; ``deadline_steps`` attaches
    the engine-enforced QoS deadline.
    """

    name: str
    weight: float = 1.0
    prompt_lens: Tuple[int, ...] = (8, 12, 16)
    max_new_lens: Tuple[int, ...] = (3, 4)
    deadline_steps: Optional[int] = None
    cancel_rate: float = 0.0
    cancel_window: Tuple[int, int] = (2, 12)   # inclusive tick range


def tenant_from_arch(
    name: str,
    *,
    weight: float = 1.0,
    cap_tokens: int = 64,
    deadline_steps: Optional[int] = None,
    cancel_rate: float = 0.0,
) -> TenantSpec:
    """Derive a tenant's traffic shape from a config-registry architecture.

    The mapping is deterministic and monotone in model size: the decimal
    magnitude of the *full* (non-smoke) parameter count sets a scale class,
    and prompt/decode bucket lengths grow with it (a 34B-class tenant sends
    ~3x the context of a 1.6B-class one).  ``cap_tokens`` clamps prompts so
    every request stays admissible on the benchmark pool.
    """
    from repro.configs.registry import get_config

    cfg = get_config(name)
    scale = 1 + min(3, max(0, int(math.log10(max(cfg.n_params(), 10))) - 9))
    lens = sorted({min(cap_tokens, 4 * scale * k) for k in (1, 2, 3)})
    max_new = (3, 4) if scale < 2 else (4, 6)
    return TenantSpec(
        name=name,
        weight=weight,
        prompt_lens=tuple(lens),
        max_new_lens=max_new,
        deadline_steps=deadline_steps,
        cancel_rate=cancel_rate,
    )


# ---------------------------------------------------------------------------
# request streams / scenarios
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One fully materialized request of a generated stream."""

    rid: int
    arrive_step: int
    tenant: str
    prompt: Tuple[int, ...]
    max_new: int
    deadline_steps: Optional[int] = None
    cancel_after: Optional[int] = None     # ticks after submission

    def to_request(self) -> Request:
        return Request(
            rid=self.rid,
            prompt=list(self.prompt),
            max_new=self.max_new,
            deadline_steps=self.deadline_steps,
            tenant=self.tenant,
        )


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, seeded traffic scenario: arrival process x tenant mix.

    ``pool`` carries the KV-pool overrides the benchmark applies when it
    builds the engine for this scenario (e.g. ``n_channels=2`` for the
    multi-tenant mix), so a scenario is self-describing end to end.
    """

    name: str
    seed: int
    arrival: ArrivalSpec
    tenants: Tuple[TenantSpec, ...]
    n_requests: int
    vocab: int = 64
    max_steps: int = 20_000
    pool: Tuple[Tuple[str, int], ...] = ()
    description: str = ""

    def pool_overrides(self) -> Dict[str, int]:
        return dict(self.pool)

    def generate(self) -> List[RequestSpec]:
        """Materialize the stream — same seed, same bytes, every time."""
        rng = np.random.default_rng(self.seed)
        arrive = self.arrival.arrivals(rng, self.n_requests)
        weights = np.asarray([t.weight for t in self.tenants], float)
        weights = weights / weights.sum()
        specs: List[RequestSpec] = []
        for rid, at in enumerate(arrive):
            tn = self.tenants[int(rng.choice(len(self.tenants), p=weights))]
            plen = int(tn.prompt_lens[int(rng.integers(len(tn.prompt_lens)))])
            max_new = int(tn.max_new_lens[int(rng.integers(len(tn.max_new_lens)))])
            prompt = tuple(int(x) for x in rng.integers(0, self.vocab, plen))
            cancel_after = None
            if tn.cancel_rate > 0.0 and rng.random() < tn.cancel_rate:
                lo, hi = tn.cancel_window
                cancel_after = int(rng.integers(lo, hi + 1))
            specs.append(RequestSpec(
                rid=rid, arrive_step=at, tenant=tn.name, prompt=prompt,
                max_new=max_new, deadline_steps=tn.deadline_steps,
                cancel_after=cancel_after,
            ))
        return specs


#: the registry the serving benchmark, its CI gate, and the tests share.
SCENARIO_NAMES: Tuple[str, ...] = (
    "steady", "bursty", "long_context", "multi_tenant", "cancel_heavy",
)


def build_scenario(name: str, *, smoke: bool = False) -> Scenario:
    """The fixed-seed scenario registry (``--smoke`` shrinks request counts
    for CI; seeds and distribution shapes stay identical)."""
    n = 36 if smoke else 400
    interactive = TenantSpec("interactive", prompt_lens=(8, 12, 16),
                             max_new_lens=(3, 4))
    if name == "steady":
        return Scenario(
            name=name, seed=901, n_requests=n,
            arrival=ArrivalSpec("steady", rate=0.5),
            tenants=(interactive,),
            pool=(("num_blocks", 32), ("max_seqs", 4)),
            description="closed-form baseline: one request every 2 ticks",
        )
    if name == "bursty":
        return Scenario(
            name=name, seed=902, n_requests=n,
            arrival=ArrivalSpec("bursty", burst_size=8, burst_gap=24.0),
            tenants=(TenantSpec("bursty", prompt_lens=(8, 12, 16),
                                max_new_lens=(6, 8)),),
            # half the steady pool, 6 decode lanes: a full burst admits more
            # sequences than the pool can grow, so decode-time extends
            # collide -> preemption + recompute-on-resume under load
            pool=(("num_blocks", 16), ("max_seqs", 6),
                  ("blocks_per_arena", 8)),
            description="thundering herds: 8-request bursts, ~24-tick gaps, "
                        "half-size pool (queueing + preemption pressure)",
        )
    if name == "long_context":
        return Scenario(
            name=name, seed=903, n_requests=max(8, (2 * n) // 3),
            arrival=ArrivalSpec("poisson", rate=1.0),
            tenants=(TenantSpec("long_context", prompt_lens=(24, 32, 40),
                                max_new_lens=(3, 4)),),
            # 4 live seqs want up to ~24 blocks: decode-time extends collide
            pool=(("num_blocks", 24), ("max_seqs", 4),
                  ("blocks_per_arena", 8)),
            description="prompt-heavy Poisson traffic near the block ceiling",
        )
    if name == "multi_tenant":
        return Scenario(
            name=name, seed=904, n_requests=n,
            arrival=ArrivalSpec("poisson", rate=0.5),
            tenants=(
                tenant_from_arch("stablelm_1_6b", weight=3.0, cap_tokens=40),
                tenant_from_arch("chatglm3_6b", weight=2.0, cap_tokens=40),
                tenant_from_arch("granite_34b", weight=1.0, cap_tokens=40,
                                 deadline_steps=160),
            ),
            pool=(("num_blocks", 48), ("max_seqs", 4), ("n_channels", 2),
                  ("blocks_per_arena", 8)),
            description="registry-derived mix on a 2-channel striped pool",
        )
    if name == "cancel_heavy":
        return Scenario(
            name=name, seed=905, n_requests=n,
            arrival=ArrivalSpec("poisson", rate=0.6),
            tenants=(
                TenantSpec("impatient", weight=2.0, prompt_lens=(8, 12, 16),
                           max_new_lens=(6, 8), cancel_rate=0.45,
                           cancel_window=(1, 4)),
                TenantSpec("deadline", weight=1.0, prompt_lens=(8, 16),
                           max_new_lens=(6, 8), deadline_steps=6),
            ),
            pool=(("num_blocks", 32), ("max_seqs", 4)),
            description="45% client cancellations + tight engine deadlines",
        )
    raise ValueError(
        f"unknown scenario {name!r} (have {', '.join(SCENARIO_NAMES)})"
    )


# ---------------------------------------------------------------------------
# deterministic serving-time model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimCost:
    """Serving-time model: fixed per-step overhead, linear per-token decode
    and prefill costs, plus the engine's priced maintenance passes.  Purely
    a function of deterministic engine counters, so tokens/s derived from
    it is byte-reproducible (unlike wall clock)."""

    step_overhead_ns: float = 2_000.0
    decode_token_ns: float = 500.0
    prefill_token_ns: float = 150.0

    def total_ns(self, eng: ServeEngine) -> float:
        return (
            self.step_overhead_ns * eng.clock
            + self.decode_token_ns * eng.tokens_decoded
            + self.prefill_token_ns * eng.tokens_prefilled
            + eng.maintenance_ns
        )


# ---------------------------------------------------------------------------
# open-loop runner
# ---------------------------------------------------------------------------

def _pct(vals: Sequence[float], q: float) -> Optional[float]:
    return round(float(np.percentile(vals, q)), 4) if vals else None


def play(
    eng: ServeEngine,
    specs: Sequence[RequestSpec],
    *,
    max_steps: int = 20_000,
    sample_every: int = 1,
    cost: SimCost = SimCost(),
) -> Dict[str, object]:
    """Drive ``specs`` through ``eng`` open-loop and return the scenario
    metrics record (see :func:`summarize`).

    Arrivals are submitted the moment the engine clock reaches their tick
    — never gated on engine readiness — and client cancellations fire on
    their own schedule.  Submission-time rejections (never-admissible
    requests) are caught and stay in the engine's ledger.  After the last
    arrival the engine is drained, so the conservation identity holds on
    the returned record.
    """
    pending = deque(sorted(specs, key=lambda s: (s.arrive_step, s.rid)))
    cancels: List[Tuple[int, int]] = []    # (due_tick, rid) min-heap
    samples: List[Dict[str, float]] = []

    def sampler(_eng: ServeEngine, sample: Dict[str, float]) -> None:
        if int(sample["clock"]) % sample_every == 0:
            samples.append(sample)

    eng.step_hooks.append(sampler)
    try:
        for _ in range(max_steps):
            while pending and pending[0].arrive_step <= eng.clock:
                spec = pending.popleft()
                try:
                    eng.submit(spec.to_request())
                except RequestRejected:
                    pass                   # recorded in eng.rejected
                else:
                    if spec.cancel_after is not None:
                        heapq.heappush(
                            cancels, (eng.clock + spec.cancel_after, spec.rid)
                        )
            while cancels and cancels[0][0] <= eng.clock:
                _, rid = heapq.heappop(cancels)
                eng.cancel(rid)            # no-op if already finished
            alive = eng.step()
            if not alive and not pending and not cancels:
                break
    finally:
        eng.step_hooks.remove(sampler)
    return summarize(eng, specs, samples, cost)


def summarize(
    eng: ServeEngine,
    specs: Sequence[RequestSpec],
    samples: Sequence[Dict[str, float]],
    cost: SimCost = SimCost(),
) -> Dict[str, object]:
    """Fold a finished run into the scenario metrics record: the ledger,
    sim-time throughput, queue/completion latency percentiles (in engine
    ticks), pool-occupancy stats, and the paper's contiguity analogue."""
    finished = list(eng.done) + list(eng.rejected) + list(eng.cancelled)
    queue_waits = [
        float(r.admit_clock - r.submit_clock)
        for r in finished if r.admit_clock >= 0
    ]
    completions = [
        float(r.finish_clock - r.submit_clock)
        for r in eng.done if r.finish_clock >= 0
    ]
    tenants = sorted({s.tenant for s in specs})
    per_tenant = {
        t: sum(1 for r in eng.done if r.tenant == t) for t in tenants
    }
    occ = [s["used_fraction"] for s in samples]
    depth = [s["queued"] for s in samples]
    batch = [s["live"] for s in samples]
    # contiguity/balance only mean something while sequences are live (a
    # drained pool trivially reports 1.0) — average over the loaded steps.
    loaded = [s for s in samples if s["live"] > 0]
    contig = [s["contiguity"] for s in loaded]
    balance = [s["channel_balance"] for s in loaded]
    dpt = [s["descriptors_per_tile"] for s in loaded]
    met = eng.metrics()
    sim_ns = cost.total_ns(eng)
    sim_s = sim_ns / 1e9
    return {
        "n": len(specs),
        "submitted": eng.submitted,
        "done": len(eng.done),
        "rejected": len(eng.rejected),
        "cancelled": len(eng.cancelled),
        "preemptions": eng.preemptions,
        "conservation_ok": (
            eng.submitted
            == len(eng.done) + len(eng.rejected) + len(eng.cancelled)
            and not eng.queue and not eng.live
        ),
        "tokens": eng.tokens_decoded,
        "tokens_prefilled": eng.tokens_prefilled,
        "clock": eng.clock,
        "sim_ns": round(sim_ns, 3),
        "tokens_per_s": round(eng.tokens_decoded / sim_s, 3) if sim_s else 0.0,
        "p50_queue_steps": _pct(queue_waits, 50),
        "p99_queue_steps": _pct(queue_waits, 99),
        "p50_complete_steps": _pct(completions, 50),
        "p99_complete_steps": _pct(completions, 99),
        "occupancy_mean": round(float(np.mean(occ)), 4) if occ else 0.0,
        "occupancy_peak": round(float(np.max(occ)), 4) if occ else 0.0,
        "queue_depth_peak": int(max(depth)) if depth else 0,
        "batch_mean": round(float(np.mean(batch)), 4) if batch else 0.0,
        "contiguity": round(float(np.mean(contig)), 4) if contig else 1.0,
        "contiguity_min": round(float(np.min(contig)), 4) if contig else 1.0,
        "descriptors_per_tile": round(float(np.mean(dpt)), 4) if dpt else 0.0,
        "channel_balance": round(float(np.mean(balance)), 4) if balance else 1.0,
        "channels": int(met["channels"]),
        "frag_end": round(met["frag"], 4),
        "injected_misses": int(met["injected_misses"]),
        "compaction_passes": int(met["compaction_passes"]),
        "blocks_migrated": int(met["blocks_migrated"]),
        "maintenance_ns": round(met["maintenance_ns"], 3),
        "done_by_tenant": per_tenant,
    }
