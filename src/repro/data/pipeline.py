"""Deterministic synthetic data pipeline.

Design goals of the 1000-node posture:

* **Deterministic addressing** — batch ``(step, dp_rank)`` is a pure function
  of those two integers (counter-based PRNG), so any host can regenerate any
  shard: restarts, elastic re-sharding, and straggler re-assignment need no
  data-state checkpoint beyond the step counter.
* **Packing** — documents of random length are packed into (B, S) with
  cross-document attention masking via loss masks (the packed-boundary mask).
* **Prefetch** — a background thread keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, RunShape


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_per_shard: int
    mean_doc_len: int = 512
    seed: int = 1234


def _rng_for(cfg: DataConfig, step: int, dp_rank: int) -> np.random.Generator:
    # counter-based: independent stream per (step, shard)
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, dp_rank])
    )


def synth_batch(cfg: DataConfig, step: int, dp_rank: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens packed from variable-length documents."""
    rng = _rng_for(cfg, step, dp_rank)
    B, S = cfg.batch_per_shard, cfg.seq_len
    tokens = np.empty((B, S + 1), np.int32)
    mask = np.ones((B, S), np.float32)
    for b in range(B):
        pos = 0
        while pos < S + 1:
            dl = int(rng.integers(cfg.mean_doc_len // 2, cfg.mean_doc_len * 2))
            dl = min(dl, S + 1 - pos)
            # low-entropy doc: random walk over vocab so loss can decrease
            start = rng.integers(0, cfg.vocab_size)
            steps = rng.integers(-3, 4, size=dl)
            doc = (start + np.cumsum(steps)) % cfg.vocab_size
            tokens[b, pos : pos + dl] = doc
            if pos > 0:
                mask[b, pos - 1] = 0.0  # don't predict across doc boundary
            pos += dl
    return {
        "tokens": tokens[:, :-1],
        "targets": tokens[:, 1:],
        "loss_mask": mask,
        "positions": np.broadcast_to(np.arange(S, dtype=np.int32)[None], (B, S)).copy(),
    }


class DataIterator:
    """Prefetching iterator over deterministic shards."""

    def __init__(
        self,
        cfg: DataConfig,
        dp_rank: int = 0,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, step, self.dp_rank)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()


def batch_for_shape(
    cfg: ModelConfig, shape: RunShape, step: int = 0, dp_rank: int = 0
) -> Dict[str, np.ndarray]:
    dcfg = DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        batch_per_shard=shape.global_batch,
    )
    return synth_batch(dcfg, step, dp_rank)
