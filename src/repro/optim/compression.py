"""Int8 gradient compression with error feedback.

Distributed-optimization trick for the DP gradient reduction at scale: each
tensor is quantized to int8 with a per-tensor scale before crossing the
(pod,) data links, and the quantization residual is carried into the next
step (error feedback keeps the scheme unbiased over time).

Two entry points:

* ``compress_grads / decompress`` — value-level quantize->dequantize with an
  error-feedback state pytree.  Under jit, pairing this with sharded params
  lets XLA move int8 (4x fewer bytes) through the all-reduce it inserts.
* ``compressed_psum`` — explicit shard_map collective for manual-DP setups:
  quantize, ``psum`` the int8 payload (plus scales), dequantize.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads as seen post-reduction, new error state)."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = _quantize(g)
        deq = _dequantize(q, s)
        return deq, g - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in outs]),
        jax.tree.unflatten(tdef, [o[1] for o in outs]),
    )


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """shard_map-level compressed all-reduce: int8 payload + f32 scale.

    Each participant quantizes locally; the int8 tensors are summed in int32
    (no overflow for <= 2^23 participants), scales are summed for the
    average-scale dequantization.  Bias from scale mismatch is bounded by
    the quantization step; error feedback upstream absorbs it.
    """
    q, s = _quantize(x)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(s, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return qsum.astype(jnp.float32) * (ssum / n)
