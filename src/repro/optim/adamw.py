"""AdamW with decoupled weight decay, global-norm clipping, and cosine
schedule — ZeRO-style: moments inherit the parameters' sharding, so the
optimizer state is fully sharded with no extra code."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def init_opt_state(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def apply_updates(
    params: Any, grads: Any, state: OptState, cfg: AdamWConfig
) -> Tuple[Any, OptState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, step), metrics
