"""RowClone-priced compaction engine (ISSUE 8, tentpole part ii).

Long-horizon churn fragments both pools this repo models:

* the **PUD region pool** (:class:`~repro.core.puma.PumaAllocator`) — free
  capacity spreads thin across subarrays, so ``pim_alloc_align`` degrades to
  worst-fit misses and fresh operand pairs stop co-locating (the
  ``fragmentation()``/PUD-executable-fraction decay the churn benchmark
  records);
* the **device tile pool** (:class:`~repro.core.arena.TilePool`) — handle
  tile lists fracture into short runs, so block tables need more DMA
  descriptors (``contiguous_run_fraction`` decay).

Compaction migrates live data to repair both.  Every move is priced through
:func:`repro.core.pud.price_migration`: a move whose source and destination
share a subarray/arena is a RowClone FPM row copy the substrate executes in
DRAM; a cross-subarray move is a host streaming copy (the substrate cannot
FPM across subarrays), plus its cacheline traffic on the channel
controllers.  With a :class:`~repro.core.controller.DramController` passed
in, the pass occupies the channel frontiers — background maintenance
competes with live traffic, which is how :mod:`repro.serve.engine` accounts
it.

Planning is separated from execution:

* ``plan_*`` are pure functions over a frozen pool state.  They choose
  **collector** subarrays/arenas (the ones worth emptying: largest
  ``free + live`` capacity) and evacuate their live rows into **dump**
  subarrays with the least free capacity, so free capacity re-concentrates;
  the tile planner additionally runs an intra-arena **run-repair** phase
  first (RowClone-cheap) that re-knits fractured handle runs.  Destination
  slots are drawn only from the pass-initial free set and never reused, so
  the whole plan is batch-safe: sources and destinations are disjoint sets
  and one gathered copy executes every move bit-exactly.
* ``compact_*`` execute a plan: forced specific-takes (the same primitives
  journal replay uses), optional byte movement on a modeled physical
  memory, a single ``compact`` journal event recording the executed moves,
  and a :class:`~repro.core.pud.MigrationCost` for the time the pass cost.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

from repro.robustness.errors import JournalReplayError

if TYPE_CHECKING:
    from repro.core.arena import TilePool
    from repro.core.controller import DramController
    from repro.core.pud import MigrationCost, PudCostModel
    from repro.core.puma import PumaAllocator

__all__ = [
    "Move",
    "CompactionPlan",
    "CompactionReport",
    "plan_allocator_compaction",
    "compact_allocator",
    "plan_pool_compaction",
    "compact_pool",
]


@dataclasses.dataclass(frozen=True)
class Move:
    """One live-data migration: row ``index`` of ``owner`` moves src -> dst.

    ``owner`` is a VA (allocator plan) or a handle ID (tile-pool plan);
    ``src``/``dst`` are region PAs or global tile indices.  ``rowclone``
    marks same-subarray/same-arena moves the substrate executes in DRAM.
    """

    owner: int
    index: int
    src: int
    dst: int
    rowclone: bool


@dataclasses.dataclass
class CompactionPlan:
    """A batch-safe list of moves against one frozen pool state."""

    subject: str                 # "PumaAllocator" | "TilePool"
    moves: List[Move] = dataclasses.field(default_factory=list)
    frag_before: float = 0.0

    def __len__(self) -> int:
        return len(self.moves)

    @property
    def rowclone_moves(self) -> List[Move]:
        return [m for m in self.moves if m.rowclone]

    @property
    def cpu_moves(self) -> List[Move]:
        return [m for m in self.moves if not m.rowclone]


@dataclasses.dataclass
class CompactionReport:
    """What one executed pass did and what it cost."""

    subject: str
    executed: int
    rowclone_rows: int
    cpu_rows: int
    bytes_moved: int
    frag_before: float
    frag_after: float
    cost: Optional["MigrationCost"] = None

    @property
    def total_ns(self) -> float:
        return self.cost.total_ns if self.cost else 0.0

    def as_dict(self) -> Dict[str, object]:
        return {
            "subject": self.subject,
            "executed": self.executed,
            "rowclone_rows": self.rowclone_rows,
            "cpu_rows": self.cpu_rows,
            "bytes_moved": self.bytes_moved,
            "frag_before": self.frag_before,
            "frag_after": self.frag_after,
            "total_ns": self.total_ns,
        }


# ---------------------------------------------------------------------------
# PUD region pool (core/puma.py)
# ---------------------------------------------------------------------------

def plan_allocator_compaction(
    al: "PumaAllocator", max_moves: int = 64
) -> CompactionPlan:
    """Plan free-capacity re-concentration for the PUD region pool.

    Regions inside one subarray are interchangeable for PUD placement, so
    the useful unit of repair is whole-subarray evacuation: empty the
    subarrays whose owned capacity (``free + live``) is largest, dumping
    their live regions into the subarrays with the *least* free capacity.
    Every such move necessarily crosses subarrays — RowClone FPM cannot —
    so allocator-level moves are all CPU-priced; the RowClone-cheap moves
    live at the tile-pool layer (:func:`plan_pool_compaction`).
    """
    plan = CompactionPlan("PumaAllocator", frag_before=al.fragmentation())
    # frozen views -----------------------------------------------------------
    live_by_sa: Dict[int, List[Tuple[int, int, int]]] = {}   # sa -> (va,k,pa)
    for va, regions in al._regions_of.items():
        if not regions:
            continue
        sas = al.amap.region_subarrays(np.asarray(regions, np.int64))
        for k, (pa, sa) in enumerate(zip(regions, sas.tolist())):
            live_by_sa.setdefault(int(sa), []).append((va, k, int(pa)))
    free: Dict[int, List[int]] = {
        sa: list(lst) for sa, lst in al._ordered.free.items() if lst
    }
    if not free:
        return plan

    # collectors: rank by the free capacity the subarray can actually reach —
    # its current free count plus as many of its live regions as the *other*
    # subarrays have free slots to absorb.  Partial evacuation still raises
    # the max-free concentration, which is the metric (ties break by id so
    # the plan is deterministic).
    total_free = sum(len(lst) for lst in free.values())

    def reach(sa: int) -> int:
        own = len(free.get(sa, ()))
        return own + min(len(live_by_sa[sa]), total_free - own)

    collectors = sorted(
        (sa for sa in live_by_sa if sa not in al._blacklisted),
        key=lambda sa: (-reach(sa), sa),
    )
    # dumps: least free capacity first (waste the least concentration
    # potential), excluding subarrays already collected — dumping into a
    # freshly emptied subarray would undo the pass.
    collected: set = set()
    for c in collectors:
        if len(plan.moves) >= max_moves:
            break
        dumps = sorted(
            (sa for sa, lst in free.items()
             if lst and sa != c and sa not in collected),
            key=lambda sa: (len(free[sa]), sa),
        )
        if not dumps:
            break
        di = 0
        planned_here: List[Move] = []
        for va, k, pa in live_by_sa[c]:
            while di < len(dumps) and not free[dumps[di]]:
                di += 1
            if di >= len(dumps):
                break               # dump capacity exhausted: partial pass
            dst = free[dumps[di]].pop()   # LIFO, matching take_from
            planned_here.append(Move(va, k, pa, dst, rowclone=False))
            if len(plan.moves) + len(planned_here) >= max_moves:
                break
        if planned_here:
            collected.add(c)
        plan.moves.extend(planned_here)
    return plan


def compact_allocator(
    al: "PumaAllocator",
    plan: Optional[CompactionPlan] = None,
    *,
    max_moves: int = 64,
    phys: Optional[np.ndarray] = None,
    model: Optional["PudCostModel"] = None,
    controller: Optional["DramController"] = None,
) -> CompactionReport:
    """Execute a compaction plan on the PUD region pool.

    Moves apply through forced specific-takes against the *current* state;
    a plan made against a state that has since changed raises
    :class:`JournalReplayError` (plan and execute within one maintenance
    step, as the serving engine does).  Pass ``phys`` to actually move the
    bytes (bit-exactness is what the churn gate asserts); the executed moves
    are journaled as one atomic ``compact`` event.
    """
    from repro.core.pud import PudCostModel, price_migration

    if plan is None:
        plan = plan_allocator_compaction(al, max_moves=max_moves)
    rb = al.region_bytes
    moved: List[List[int]] = []
    touched = set()
    cpu_pas: List[int] = []
    for m in plan.moves:
        regions = al._regions_of.get(m.owner)
        if regions is None or regions[m.index] != m.src:
            raise JournalReplayError(
                "compaction plan is stale: source region moved",
                va=m.owner, k=m.index,
            )
        dst_sa = int(al.amap.region_subarrays(np.asarray([m.dst], np.int64))[0])
        if not al._ordered.take_specific(dst_sa, m.dst):
            raise JournalReplayError(
                "compaction plan is stale: destination region not free",
                pa=m.dst, sa=dst_sa,
            )
        if phys is not None:
            phys[m.dst:m.dst + rb] = phys[m.src:m.src + rb]
        src_sa = int(al.amap.region_subarrays(np.asarray([m.src], np.int64))[0])
        regions[m.index] = m.dst
        al._ordered.add_region(src_sa, m.src)
        if al.n_channels > 1:
            chs = al.amap.region_channels(np.asarray([m.src, m.dst], np.int64))
            al._used_per_channel[int(chs[0])] -= 1
            al._used_per_channel[int(chs[1])] += 1
        touched.add(m.owner)
        moved.append([m.owner, m.index, m.src, m.dst])
        if not m.rowclone:
            lines = np.arange(0, rb, 64, dtype=np.int64)
            cpu_pas.extend((m.src + lines).tolist())
            cpu_pas.extend((m.dst + lines).tolist())
    from repro.core.allocators import Extent

    for va in touched:
        alloc = al._allocations[va]
        alloc.extents = [
            Extent(i * rb, pa, rb)
            for i, pa in enumerate(al._regions_of[va])
        ]
        alloc.__post_init__()
    if moved and al.journal is not None:
        al.journal.append("compact", moves=moved)
    cost = price_migration(
        [int(al.amap.region_subarrays(np.asarray([m.dst], np.int64))[0])
         for m in plan.rowclone_moves],
        len(plan.cpu_moves),
        rb,
        channels=al.n_channels,
        model=model or PudCostModel(),
        controller=controller,
        cpu_pas=np.asarray(cpu_pas, np.int64) if cpu_pas else None,
    ) if moved else None
    return CompactionReport(
        subject="PumaAllocator",
        executed=len(moved),
        rowclone_rows=len(plan.rowclone_moves) if moved else 0,
        cpu_rows=len(plan.cpu_moves) if moved else 0,
        bytes_moved=len(moved) * rb,
        frag_before=plan.frag_before,
        frag_after=al.fragmentation(),
        cost=cost,
    )


# ---------------------------------------------------------------------------
# Device tile pool (core/arena.py)
# ---------------------------------------------------------------------------

def plan_pool_compaction(
    pool: "TilePool", max_moves: int = 128
) -> CompactionPlan:
    """Plan tile-pool repair: run repair first, then arena evacuation.

    Phase 1 (**run repair**, RowClone-priced): for every live handle, a tile
    whose predecessor sits in the same arena but not adjacently moves into
    the free slot right after the predecessor — an intra-arena (same
    subarray) row copy that directly re-knits ``contiguous_run_fraction``.

    Phase 2 (**arena evacuation**, CPU-priced): mirrors
    :func:`plan_allocator_compaction` at arena granularity — empty the
    arenas with the most owned capacity into the arenas with the least free
    capacity, so future worst-fit allocations find long free runs again.

    Destinations come only from the pass-initial free set and are never
    reused; sources are live tiles.  The two sets are disjoint, so one
    batched gather/scatter copy (``pool_block_copy``) executes the whole
    plan safely.
    """
    tpa = pool.tiles_per_arena
    plan = CompactionPlan("TilePool", frag_before=pool.fragmentation())
    free: List[set] = [set(lst) for lst in pool._free]
    # virtual handle tile lists: phase 2 must see phase 1's placements
    vtiles: Dict[int, List[int]] = {
        hid: list(h.tiles) for hid, h in pool._handles.items()
    }

    # -- phase 1: intra-arena run repair -------------------------------------
    for hid in sorted(vtiles):
        tiles = vtiles[hid]
        for k in range(1, len(tiles)):
            if len(plan.moves) >= max_moves:
                break
            prev, cur = tiles[k - 1], tiles[k]
            want = prev + 1
            if cur == want or want // tpa != prev // tpa:
                continue
            a, s = divmod(want, tpa)
            if s not in free[a]:
                continue
            free[a].discard(s)
            plan.moves.append(Move(hid, k, cur, want, rowclone=True))
            tiles[k] = want
        if len(plan.moves) >= max_moves:
            return plan

    # -- phase 2: arena evacuation -------------------------------------------
    # Victims group by handle: a handle's tiles inside the collector arena
    # move *together* into one contiguous free run of a dump arena (best-fit
    # over runs), so evacuation repairs contiguity instead of shredding it.
    # A group with no fitting run stays put — scattering it would trade the
    # pool-level fragmentation win for a handle-level contiguity loss.
    live_by_arena: Dict[int, Dict[int, List[Tuple[int, int]]]] = {}
    for hid, tiles in vtiles.items():
        for k, t in enumerate(tiles):
            live_by_arena.setdefault(t // tpa, {}).setdefault(
                hid, []
            ).append((k, t))
    collectors = sorted(
        live_by_arena,
        key=lambda a: (
            -(len(free[a]) + sum(len(g) for g in live_by_arena[a].values())),
            a,
        ),
    )

    def runs_of(slots: set) -> List[Tuple[int, int]]:
        out, lst = [], sorted(slots)
        i = 0
        while i < len(lst):
            j = i
            while j + 1 < len(lst) and lst[j + 1] == lst[j] + 1:
                j += 1
            out.append((lst[i], j - i + 1))
            i = j + 1
        return out

    collected: set = set()
    for c in collectors:
        if len(plan.moves) >= max_moves:
            break
        planned_here: List[Move] = []
        for hid in sorted(live_by_arena[c]):
            group = sorted(live_by_arena[c][hid])        # by index k
            need = len(group)
            # best-fit run across dump arenas: smallest run that fits,
            # ties to the fullest arena then lowest id (deterministic).
            best = None
            for a in range(pool.n_arenas):
                if a == c or a in collected or not free[a]:
                    continue
                for start, length in runs_of(free[a]):
                    if length >= need and (
                        best is None
                        or (length, len(free[a]), a) < best[:3]
                    ):
                        best = (length, len(free[a]), a, start)
            if best is None:
                continue
            _, _, a, start = best
            for off, (k, t) in enumerate(group):
                free[a].discard(start + off)
                planned_here.append(
                    Move(hid, k, t, a * tpa + start + off, rowclone=False)
                )
            if len(plan.moves) + len(planned_here) >= max_moves:
                break
        if planned_here:
            collected.add(c)
        plan.moves.extend(planned_here)
    return plan


def compact_pool(
    pool: "TilePool",
    plan: Optional[CompactionPlan] = None,
    *,
    max_moves: int = 128,
    tile_bytes: int = 8192,
    model: Optional["PudCostModel"] = None,
    controller: Optional["DramController"] = None,
) -> CompactionReport:
    """Execute a tile-pool compaction plan (bookkeeping only — the caller
    owns the device buffers and applies the plan's moves to them; see
    :meth:`repro.core.kv_pool.PagedKVPool.compact` for the batched
    ``pool_block_copy`` data path).  Executed moves are journaled as one
    ``compact`` event; the cost prices phase-1 moves as RowClone rows on
    the arena's channel (``arena % n_channels``) and phase-2 moves as host
    copies of ``tile_bytes`` each.
    """
    from repro.core.pud import PudCostModel, price_migration

    if plan is None:
        plan = plan_pool_compaction(pool, max_moves=max_moves)
    tpa = pool.tiles_per_arena
    moved: List[List[int]] = []
    for m in plan.moves:
        h = pool._handles.get(m.owner)
        if h is None or h.tiles[m.index] != m.src:
            raise JournalReplayError(
                "compaction plan is stale: source tile moved",
                hid=m.owner, k=m.index,
            )
        a, s = divmod(m.dst, tpa)
        if pool._take_slot(a, s) != m.dst:
            raise JournalReplayError(
                "compaction plan is stale: destination tile not free",
                tile=m.dst,
            )
        h.tiles[m.index] = m.dst
        pool._give_back(m.src)
        moved.append([m.owner, m.index, m.src, m.dst])
    if moved and pool.journal is not None:
        pool.journal.append("compact", moves=moved)
    cost = price_migration(
        [m.dst // tpa for m in plan.rowclone_moves],
        len(plan.cpu_moves),
        tile_bytes,
        channels=pool.n_channels,
        model=model or PudCostModel(),
        controller=controller,
    ) if moved else None
    return CompactionReport(
        subject="TilePool",
        executed=len(moved),
        rowclone_rows=len(plan.rowclone_moves) if moved else 0,
        cpu_rows=len(plan.cpu_moves) if moved else 0,
        bytes_moved=len(moved) * tile_bytes,
        frag_before=plan.frag_before,
        frag_after=pool.fragmentation(),
        cost=cost,
    )
