"""Typed error taxonomy for the PUMA stack (ISSUE 7 tentpole, part 1).

PUMA's central behaviour is *graceful degradation*: a misaligned operand
pair falls back to the host CPU instead of failing the operation.  The same
discipline applies to the software stack — every failure an allocator, the
translation layer, the PUD executor, or the serving engine can hit is a
*typed*, catchable condition, never a bare ``ValueError``/``MemoryError``
whose meaning depends on the call site.

The taxonomy is deliberately multiple-inheritance-compatible with the
builtin types the seed code raised, so existing callers (and tests) that
catch ``MemoryError`` or ``ValueError`` keep working:

* :class:`PumaAllocError` **is a** ``MemoryError`` — allocation failures;
  :class:`PoolExhausted` and its leaves distinguish which pool ran dry
  (PUD region pool, huge-page pool, base-page budget, KV tile pool).
* :class:`TranslationError` **is a** ``ValueError`` — VA->PA translation
  on unmapped/out-of-range offsets.
* :class:`PudExecError` **is a** ``RuntimeError`` — an in-DRAM op failed
  mid-flight (injected RowClone fault, blacklisted subarray).
* :class:`RequestRejected` — the serving engine explicitly refused work it
  can never (or no longer) serve; :class:`DeadlineExceeded` is the
  per-request deadline/cancellation leaf.
* :class:`InvariantViolation` **is an** ``AssertionError`` — the invariant
  checker (:mod:`repro.robustness.invariants`) found pool-state corruption
  (extent overlap, double free, leak).

Errors carry structured context via keyword fields (``req``, ``subarray``,
``wanted``/``free``, ...) so chaos benchmarks and stall reports can
aggregate failures without parsing messages.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

__all__ = [
    "PumaError",
    "PumaAllocError",
    "PoolExhausted",
    "HugePageExhausted",
    "BasePageExhausted",
    "TilePoolExhausted",
    "DoubleFree",
    "TranslationError",
    "PudExecError",
    "RowCloneFault",
    "RequestRejected",
    "DeadlineExceeded",
    "ClientCancelled",
    "EngineStalled",
    "InvariantViolation",
    "JournalReplayError",
]


class PumaError(Exception):
    """Root of the PUMA error taxonomy.

    ``ctx`` holds machine-readable context (counts, ids, addresses) so
    reports aggregate failures structurally rather than by message text.
    """

    def __init__(self, message: str = "", **ctx: Any):
        super().__init__(message)
        self.ctx: Dict[str, Any] = ctx

    def __str__(self) -> str:  # message first, context appended when present
        base = super().__str__()
        if not self.ctx:
            return base
        kv = ", ".join(f"{k}={v!r}" for k, v in sorted(self.ctx.items()))
        return f"{base} [{kv}]" if base else f"[{kv}]"


# -- allocation ---------------------------------------------------------------

class PumaAllocError(PumaError, MemoryError):
    """An allocation request could not be satisfied."""


class PoolExhausted(PumaAllocError):
    """A memory pool ran out of capacity (possibly transiently).

    ``injected=True`` marks failures induced by a
    :class:`~repro.robustness.faults.FaultInjector` — the retry/backoff
    fallback chain treats those as transient.
    """

    def __init__(self, message: str = "", *, injected: bool = False, **ctx: Any):
        super().__init__(message, **ctx)
        self.injected = injected


class HugePageExhausted(PoolExhausted):
    """The boot-time huge-page reservation is empty (or injector-denied)."""


class BasePageExhausted(PoolExhausted):
    """The 4 KB base-page free budget is empty — the end of the fallback
    chain; there is no cheaper tier below base pages."""


class TilePoolExhausted(PoolExhausted):
    """The device-side tile/KV-block pool has no free tiles."""


class DoubleFree(PumaError, KeyError):
    """A handle/allocation was freed that is not live (double free or
    foreign pointer) — KeyError-compatible with the seed behaviour."""


# -- translation --------------------------------------------------------------

class TranslationError(PumaError, ValueError):
    """VA->PA translation failed: unmapped offset, out-of-range region, or
    an empty (zero-extent) allocation — ValueError-compatible with the seed
    raises so existing ``pytest.raises(ValueError)`` pins still hold."""


# -- PUD execution ------------------------------------------------------------

class PudExecError(PumaError, RuntimeError):
    """An in-DRAM operation failed to complete in DRAM."""


class RowCloneFault(PudExecError):
    """A RowClone/Ambit row operation faulted mid-flight.  ``permanent=True``
    means the subarray should be blacklisted and its rows remapped."""

    def __init__(self, message: str = "", *, subarray: int = -1,
                 permanent: bool = False, **ctx: Any):
        super().__init__(message, subarray=subarray, **ctx)
        self.subarray = subarray
        self.permanent = permanent


# -- serving ------------------------------------------------------------------

class RequestRejected(PumaError):
    """The serving engine explicitly refused a request (admission control,
    capacity, starvation).  ``rid`` identifies the request."""

    def __init__(self, message: str = "", *, rid: Optional[int] = None, **ctx: Any):
        super().__init__(message, rid=rid, **ctx)
        self.rid = rid


class DeadlineExceeded(RequestRejected):
    """A request's per-request deadline elapsed before completion."""


class ClientCancelled(RequestRejected):
    """The client withdrew the request (``ServeEngine.cancel``) before it
    completed — early cancellation, not an engine-side failure."""


class EngineStalled(PumaError):
    """The engine made no progress: nothing live, nothing admissible, work
    still queued.  Carries the stall report for diagnosis."""

    def __init__(self, message: str = "", *, report: Optional[Dict] = None, **ctx: Any):
        super().__init__(message, **ctx)
        self.report = report or {}


# -- invariants ---------------------------------------------------------------

class InvariantViolation(PumaError, AssertionError):
    """Pool-state corruption detected by the invariant checker."""


class JournalReplayError(PumaError, RuntimeError):
    """A journal event could not be applied during forced replay — the log
    is corrupt (truncated mid-event, tampered payload) or is being replayed
    against a machine with different geometry than the one that wrote it."""
