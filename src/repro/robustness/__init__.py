"""Fault injection, typed failures, and invariant auditing (ISSUE 7).

Import structure: :mod:`repro.robustness.errors` and
:mod:`repro.robustness.faults` are dependency-free (the core layers import
*them*), while :mod:`repro.robustness.invariants` inspects the core pools
and therefore imports core.  To keep ``repro.core.* -> repro.robustness.
errors`` acyclic, ``invariants`` is loaded lazily via ``__getattr__``.
"""
from repro.robustness.errors import (  # noqa: F401
    BasePageExhausted,
    ClientCancelled,
    DeadlineExceeded,
    DoubleFree,
    EngineStalled,
    HugePageExhausted,
    InvariantViolation,
    JournalReplayError,
    PoolExhausted,
    PudExecError,
    PumaAllocError,
    PumaError,
    RequestRejected,
    RowCloneFault,
    TilePoolExhausted,
    TranslationError,
)
from repro.robustness.faults import FaultInjector, FaultPlan, FaultStats  # noqa: F401

# invariants / journal / compaction inspect the core pools, so they load
# lazily (core imports errors/faults/journal-type-hints from us).
_LAZY_INVARIANTS = ("InvariantReport", "check_allocator", "check_tile_pool",
                    "check_kv_pool", "check_engine")
_LAZY_JOURNAL = ("Event", "Journal", "snapshot_allocator", "restore_allocator",
                 "snapshot_pool", "restore_pool", "replay_allocator",
                 "replay_pool", "replay_kv_pool", "allocator_digest",
                 "pool_digest", "kv_pool_digest")
_LAZY_COMPACTION = ("Move", "CompactionPlan", "CompactionReport",
                    "plan_allocator_compaction", "compact_allocator",
                    "plan_pool_compaction", "compact_pool")

__all__ = [
    "PumaError", "PumaAllocError", "PoolExhausted", "HugePageExhausted",
    "BasePageExhausted", "TilePoolExhausted", "DoubleFree",
    "TranslationError", "PudExecError", "RowCloneFault", "RequestRejected",
    "DeadlineExceeded", "ClientCancelled", "EngineStalled", "InvariantViolation",
    "JournalReplayError",
    "FaultPlan", "FaultStats", "FaultInjector",
    *_LAZY_INVARIANTS, *_LAZY_JOURNAL, *_LAZY_COMPACTION,
]


def __getattr__(name):
    if name in _LAZY_INVARIANTS:
        from repro.robustness import invariants

        return getattr(invariants, name)
    if name in _LAZY_JOURNAL:
        from repro.robustness import journal

        return getattr(journal, name)
    if name in _LAZY_COMPACTION:
        from repro.robustness import compaction

        return getattr(compaction, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
