"""Fault injection, typed failures, and invariant auditing (ISSUE 7).

Import structure: :mod:`repro.robustness.errors` and
:mod:`repro.robustness.faults` are dependency-free (the core layers import
*them*), while :mod:`repro.robustness.invariants` inspects the core pools
and therefore imports core.  To keep ``repro.core.* -> repro.robustness.
errors`` acyclic, ``invariants`` is loaded lazily via ``__getattr__``.
"""
from repro.robustness.errors import (  # noqa: F401
    BasePageExhausted,
    DeadlineExceeded,
    DoubleFree,
    EngineStalled,
    HugePageExhausted,
    InvariantViolation,
    PoolExhausted,
    PudExecError,
    PumaAllocError,
    PumaError,
    RequestRejected,
    RowCloneFault,
    TilePoolExhausted,
    TranslationError,
)
from repro.robustness.faults import FaultInjector, FaultPlan, FaultStats  # noqa: F401

_LAZY = ("InvariantReport", "check_allocator", "check_tile_pool",
         "check_kv_pool", "check_engine")

__all__ = [
    "PumaError", "PumaAllocError", "PoolExhausted", "HugePageExhausted",
    "BasePageExhausted", "TilePoolExhausted", "DoubleFree",
    "TranslationError", "PudExecError", "RowCloneFault", "RequestRejected",
    "DeadlineExceeded", "EngineStalled", "InvariantViolation",
    "FaultPlan", "FaultStats", "FaultInjector", *_LAZY,
]


def __getattr__(name):
    if name in _LAZY:
        from repro.robustness import invariants

        return getattr(invariants, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
