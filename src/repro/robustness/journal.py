"""Crash-consistent allocator journaling (ISSUE 8, tentpole part iii).

An append-only :class:`Journal` records the *outcome* of every state-changing
operation on a pool allocator — which physical regions an allocation actually
received, which tiles a handle actually got, which rows a blacklist remap or
a compaction pass actually moved.  Because outcomes (not requests) are
logged, replay is **forced**: it re-applies the recorded placements through
specific-take primitives (:meth:`_OrderedArray.take_specific`,
:meth:`TilePool._take_slot`) instead of re-running worst-fit, so the rebuilt
state is bit-exact regardless of heap tie-breaks, lazy-heap staleness, or
RNG state — the property the CI churn gate asserts.

Crash model: a crash truncates the log at an arbitrary event boundary
(events are atomic; a torn event is treated as absent, like a WAL record
without its commit).  :meth:`Journal.crash_copy` produces the truncated
survivor; :func:`replay_allocator` / :func:`replay_pool` /
:func:`replay_kv_pool` rebuild the pre-crash state, which must then pass
every auditor in :mod:`repro.robustness.invariants` — that round trip is
what "crash-consistent" means here.

Snapshots bound replay cost on long-horizon churn: :meth:`Journal.snapshot`
captures a full serialized state (see :func:`snapshot_allocator` /
:func:`snapshot_pool`) and truncates the log; replay restores the snapshot
and applies only the tail.  ``to_json``/``from_json`` round-trip the whole
journal through plain JSON for on-disk persistence.

This module is runtime-dependency-free with respect to ``repro.core`` (the
core pools import *us* for type hints only); every core import here is
deferred into the replay/snapshot functions, mirroring how
:mod:`repro.robustness.invariants` stays acyclic.
"""
from __future__ import annotations

import dataclasses
import json
from typing import TYPE_CHECKING, Any, Dict, List, Optional

import numpy as np

from repro.robustness.errors import JournalReplayError

if TYPE_CHECKING:
    from repro.core.arena import TilePool
    from repro.core.kv_pool import KVPoolConfig, PagedKVPool
    from repro.core.puma import PumaAllocator

__all__ = [
    "Event",
    "Journal",
    "snapshot_allocator",
    "restore_allocator",
    "snapshot_pool",
    "restore_pool",
    "replay_allocator",
    "replay_pool",
    "replay_kv_pool",
    "allocator_digest",
    "pool_digest",
    "kv_pool_digest",
]


@dataclasses.dataclass(frozen=True)
class Event:
    """One durable log record: an operation *outcome*."""

    seq: int
    kind: str
    data: Dict[str, Any]

    def to_obj(self) -> Dict[str, Any]:
        return {"seq": self.seq, "kind": self.kind, **self.data}

    @staticmethod
    def from_obj(obj: Dict[str, Any]) -> "Event":
        d = dict(obj)
        return Event(seq=d.pop("seq"), kind=d.pop("kind"), data=d)


class Journal:
    """Append-only event log with optional snapshot base.

    One journal instance is attached to one subject (a ``PumaAllocator``, a
    ``TilePool``, or a ``PagedKVPool`` — the KV pool shares its journal with
    its inner tile pool, interleaving slot-level and tile-level events in
    one totally ordered log).
    """

    def __init__(self):
        self.base: Optional[Dict[str, Any]] = None   # snapshot state, if any
        self.base_seq: int = 0          # events before this seq are folded in
        self.events: List[Event] = []
        self._next_seq = 0

    def __len__(self) -> int:
        return len(self.events)

    def append(self, kind: str, **data: Any) -> Event:
        ev = Event(self._next_seq, kind, data)
        self._next_seq += 1
        self.events.append(ev)
        return ev

    # -- snapshot / truncation ------------------------------------------------
    def snapshot(self, state: Dict[str, Any]) -> None:
        """Install ``state`` as the new replay base and truncate the log —
        the WAL-checkpoint analogue.  Replay cost after this is O(tail)."""
        self.base = state
        self.base_seq = self._next_seq
        self.events = []

    # -- crash model ----------------------------------------------------------
    def crash_copy(self, keep_events: int) -> "Journal":
        """The journal a crash would leave behind: the snapshot base plus the
        first ``keep_events`` tail events (atomic-event truncation)."""
        j = Journal()
        j.base = json.loads(json.dumps(self.base)) if self.base else None
        j.base_seq = self.base_seq
        j.events = list(self.events[:keep_events])
        j._next_seq = j.events[-1].seq + 1 if j.events else j.base_seq
        return j

    # -- persistence ----------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "base": self.base,
            "base_seq": self.base_seq,
            "events": [e.to_obj() for e in self.events],
        })

    @staticmethod
    def from_json(text: str) -> "Journal":
        obj = json.loads(text)
        j = Journal()
        j.base = obj["base"]
        j.base_seq = obj["base_seq"]
        j.events = [Event.from_obj(e) for e in obj["events"]]
        j._next_seq = j.events[-1].seq + 1 if j.events else j.base_seq
        return j


def _need(cond: bool, msg: str, **ctx: Any) -> None:
    if not cond:
        raise JournalReplayError(msg, **ctx)


# ---------------------------------------------------------------------------
# PumaAllocator: snapshot / restore / forced replay
# ---------------------------------------------------------------------------

def snapshot_allocator(al: "PumaAllocator") -> Dict[str, Any]:
    """Serialize the durable state of a :class:`PumaAllocator`.

    Only conservation-relevant state is captured (free lists, live
    allocations, quarantine, blacklist, the counters the auditors check).
    QoS-only counters (align hits/misses, failed/injected counts) are
    telemetry, not state — they restore to zero.
    """
    return {
        "subject": "PumaAllocator",
        "free": [[int(sa), [int(pa) for pa in lst]]
                 for sa, lst in sorted(al._ordered.free.items()) if lst],
        "allocs": [[int(va), int(al._allocations[va].size),
                    [int(pa) for pa in regions]]
                   for va, regions in sorted(al._regions_of.items())],
        "quarantined": [int(pa) for pa in al._quarantined],
        "blacklisted": sorted(int(sa) for sa in al._blacklisted),
        "va_next": int(al._va_next),
        "preallocated": int(al.stats.preallocated_regions),
    }


def restore_allocator(
    state: Dict[str, Any],
    mem,
    *,
    amap=None,
    stripe_channels: bool = False,
) -> "PumaAllocator":
    """Rebuild a :class:`PumaAllocator` from a snapshot onto fresh ``mem``.

    Huge pages covering any region the snapshot owns are withdrawn from
    ``mem.free_huge`` so a post-restore ``pim_preallocate`` cannot hand the
    same physical rows out twice.
    """
    from repro.core.allocators import HUGE_PAGE, Allocation, Extent
    from repro.core.puma import PumaAllocator

    _need(state.get("subject") == "PumaAllocator",
          f"snapshot subject {state.get('subject')!r} is not a PumaAllocator")
    al = PumaAllocator(mem, amap, stripe_channels=stripe_channels)
    rb = al.region_bytes

    owned: List[int] = []
    for sa, lst in state["free"]:
        for pa in lst:
            al._ordered.add_region(int(sa), int(pa))
            owned.append(int(pa))
    for va, size, regions in state["allocs"]:
        extents = [Extent(i * rb, int(pa), rb) for i, pa in enumerate(regions)]
        alloc = Allocation(int(va), int(size), extents, al.name)
        al._allocations[int(va)] = alloc
        al._regions_of[int(va)] = [int(pa) for pa in regions]
        owned.extend(int(pa) for pa in regions)
        al.stats.live_allocations += 1
        al.stats.regions_in_use += len(regions)
        if al.n_channels > 1:
            al._used_per_channel += np.bincount(
                al.amap.region_channels(np.asarray(regions, np.int64)),
                minlength=al.n_channels,
            )
        else:
            al._used_per_channel[0] += len(regions)
    al._quarantined = [int(pa) for pa in state["quarantined"]]
    owned.extend(al._quarantined)
    al.stats.quarantined_regions = len(al._quarantined)
    al._blacklisted = set(int(sa) for sa in state["blacklisted"])
    al._va_next = int(state["va_next"])
    al.stats.preallocated_regions = int(state["preallocated"])

    hps = {pa - pa % HUGE_PAGE for pa in owned}
    mem.free_huge = [pa for pa in mem.free_huge if pa not in hps]
    return al


def _force_take_region(al: "PumaAllocator", pa: int) -> int:
    sa = int(al.amap.region_subarrays(np.asarray([pa], np.int64))[0])
    _need(al._ordered.take_specific(sa, pa),
          f"region {pa:#x} (subarray {sa}) not free at replay", pa=pa, sa=sa)
    return sa


def _shift_channel(al: "PumaAllocator", old_pa: int, new_pa: int) -> None:
    if al.n_channels > 1:
        chs = al.amap.region_channels(np.asarray([old_pa, new_pa], np.int64))
        al._used_per_channel[int(chs[0])] -= 1
        al._used_per_channel[int(chs[1])] += 1


def _rebuild_extents(al: "PumaAllocator", va: int) -> None:
    from repro.core.allocators import Extent

    rb = al.region_bytes
    alloc = al._allocations[va]
    alloc.extents = [
        Extent(i * rb, pa, rb) for i, pa in enumerate(al._regions_of[va])
    ]
    alloc.__post_init__()


def apply_allocator_event(al: "PumaAllocator", ev: Event) -> None:
    """Force one journal event onto ``al`` (replay primitive).

    Kinds: ``prealloc`` / ``alloc`` / ``free`` / ``blacklist`` / ``compact``.
    """
    from repro.core.allocators import HUGE_PAGE, Allocation, Extent

    rb = al.region_bytes
    d = ev.data
    if ev.kind == "prealloc":
        hps = [int(pa) for pa in d["hps"]]
        want = set(hps)
        al.mem.free_huge = [pa for pa in al.mem.free_huge if pa not in want]
        per_hp = np.arange(HUGE_PAGE // rb, dtype=np.int64) * rb
        rpas = (np.asarray(hps, dtype=np.int64)[:, None] + per_hp).ravel()
        sas = al.amap.region_subarrays(rpas)
        al.stats.preallocated_regions += len(rpas)
        if al._blacklisted:
            bl = np.fromiter(al._blacklisted, dtype=np.int64)
            bad = np.isin(sas, bl)
            if bad.any():
                al._quarantined.extend(rpas[bad].tolist())
                al.stats.quarantined_regions += int(bad.sum())
                rpas, sas = rpas[~bad], sas[~bad]
        al._ordered.add_regions(sas, rpas)
    elif ev.kind == "alloc":
        va, size = int(d["va"]), int(d["size"])
        regions = [int(pa) for pa in d["regions"]]
        for pa in regions:
            _force_take_region(al, pa)
        extents = [Extent(i * rb, pa, rb) for i, pa in enumerate(regions)]
        al._allocations[va] = Allocation(va, size, extents, al.name)
        al._regions_of[va] = regions
        al._va_next = max(al._va_next, va + len(regions) * rb)
        al.stats.live_allocations += 1
        al.stats.regions_in_use += len(regions)
        if al.n_channels > 1:
            al._used_per_channel += np.bincount(
                al.amap.region_channels(np.asarray(regions, np.int64)),
                minlength=al.n_channels,
            )
        else:
            al._used_per_channel[0] += len(regions)
    elif ev.kind == "free":
        va = int(d["va"])
        _need(va in al._allocations, f"free of unknown va {va:#x}", va=va)
        regions = al._regions_of.pop(va)
        del al._allocations[va]
        al._release(regions)
        al.stats.live_allocations -= 1
        al.stats.regions_in_use -= len(regions)
    elif ev.kind == "blacklist":
        sa = int(d["sa"])
        al._blacklisted.add(sa)
        for pa in d["drained"]:
            _need(al._ordered.take_specific(sa, int(pa)),
                  f"drained region {int(pa):#x} not free at replay", pa=pa)
            al._quarantined.append(int(pa))
            al.stats.quarantined_regions += 1
        touched = set()
        for va, k, old_pa, new_pa in d["remaps"]:
            va, k, old_pa, new_pa = int(va), int(k), int(old_pa), int(new_pa)
            regions = al._regions_of.get(va)
            _need(regions is not None and regions[k] == old_pa,
                  f"remap target mismatch at va {va:#x}[{k}]", va=va, k=k)
            _force_take_region(al, new_pa)
            regions[k] = new_pa
            al._quarantined.append(old_pa)
            al.stats.quarantined_regions += 1
            al.stats.remapped_regions += 1
            _shift_channel(al, old_pa, new_pa)
            touched.add(va)
        for va in touched:
            _rebuild_extents(al, va)
    elif ev.kind == "compact":
        touched = set()
        for va, k, old_pa, new_pa in d["moves"]:
            va, k, old_pa, new_pa = int(va), int(k), int(old_pa), int(new_pa)
            regions = al._regions_of.get(va)
            _need(regions is not None and regions[k] == old_pa,
                  f"compaction move mismatch at va {va:#x}[{k}]", va=va, k=k)
            _force_take_region(al, new_pa)
            regions[k] = new_pa
            old_sa = int(al.amap.region_subarrays(
                np.asarray([old_pa], np.int64))[0])
            al._ordered.add_region(old_sa, old_pa)
            _shift_channel(al, old_pa, new_pa)
            touched.add(va)
        for va in touched:
            _rebuild_extents(al, va)
    else:
        raise JournalReplayError(
            f"unknown allocator journal event {ev.kind!r}", kind=ev.kind
        )


def replay_allocator(
    journal: Journal,
    mem,
    *,
    amap=None,
    stripe_channels: bool = False,
) -> "PumaAllocator":
    """Rebuild a :class:`PumaAllocator` from a (possibly crash-truncated)
    journal: restore the snapshot base if present, then force-apply the tail.

    ``mem`` must be a *fresh* :class:`PhysicalMemory` built with the same
    geometry/seed as the original machine (its huge-page pool is consumed as
    recorded ``prealloc`` events replay).
    """
    from repro.core.puma import PumaAllocator

    if journal.base is not None:
        al = restore_allocator(
            journal.base, mem, amap=amap, stripe_channels=stripe_channels
        )
    else:
        al = PumaAllocator(mem, amap, stripe_channels=stripe_channels)
    for ev in journal.events:
        apply_allocator_event(al, ev)
    return al


def allocator_digest(al: "PumaAllocator") -> str:
    """Canonical JSON digest of an allocator's durable state — two
    allocators with equal digests are bit-identical for every auditor and
    every future placement decision."""
    return json.dumps(snapshot_allocator(al), sort_keys=True)


# ---------------------------------------------------------------------------
# TilePool: snapshot / restore / forced replay
# ---------------------------------------------------------------------------

def snapshot_pool(pool: "TilePool") -> Dict[str, Any]:
    """Serialize the durable state of a :class:`TilePool`."""
    return {
        "subject": "TilePool",
        "geometry": [pool.n_arenas, pool.tiles_per_arena,
                     pool.policy, pool.n_channels],
        "free": [[int(s) for s in lst] for lst in pool._free],
        "handles": [[int(hid), [int(t) for t in h.tiles]]
                    for hid, h in sorted(pool._handles.items())],
        "next_hid": int(pool._next_hid),
    }


def restore_pool(state: Dict[str, Any], seed: int = 0) -> "TilePool":
    from repro.core.arena import TileHandle, TilePool

    _need(state.get("subject") == "TilePool",
          f"snapshot subject {state.get('subject')!r} is not a TilePool")
    n_arenas, tpa, policy, n_channels = state["geometry"]
    pool = TilePool(n_arenas, tpa, policy, seed=seed, n_channels=n_channels)
    pool._free = [[int(s) for s in lst] for lst in state["free"]]
    for a in range(n_arenas):
        pool._push_count(a)
    for hid, tiles in state["handles"]:
        pool._handles[int(hid)] = TileHandle(int(hid), [int(t) for t in tiles])
    pool._next_hid = int(state["next_hid"])
    return pool


def _force_take_tile(pool: "TilePool", tile: int) -> None:
    arena, slot = divmod(int(tile), pool.tiles_per_arena)
    _need(pool._take_slot(arena, slot) == tile,
          f"tile {tile} (arena {arena}, slot {slot}) not free at replay",
          tile=tile)


def apply_pool_event(pool: "TilePool", ev: Event) -> None:
    """Force one journal event onto a tile pool.

    Kinds: ``alloc`` / ``extend`` / ``free`` / ``compact``.
    """
    from repro.core.arena import TileHandle

    d = ev.data
    if ev.kind == "alloc":
        hid = int(d["hid"])
        tiles = [int(t) for t in d["tiles"]]
        for t in tiles:
            _force_take_tile(pool, t)
        pool._handles[hid] = TileHandle(hid, tiles)
        pool._next_hid = max(pool._next_hid, hid + 1)
        pool.stats.allocs += 1
    elif ev.kind == "extend":
        hid, tile = int(d["hid"]), int(d["tile"])
        _need(hid in pool._handles, f"extend of dead handle {hid}", hid=hid)
        _force_take_tile(pool, tile)
        pool._handles[hid].tiles.append(tile)
    elif ev.kind == "free":
        hid = int(d["hid"])
        _need(hid in pool._handles, f"free of dead handle {hid}", hid=hid)
        h = pool._handles.pop(hid)
        for t in h.tiles:
            pool._give_back(t)
        pool.stats.frees += 1
    elif ev.kind == "compact":
        for hid, k, old, new in d["moves"]:
            hid, k, old, new = int(hid), int(k), int(old), int(new)
            h = pool._handles.get(hid)
            _need(h is not None and h.tiles[k] == old,
                  f"compaction move mismatch at handle {hid}[{k}]", hid=hid)
            _force_take_tile(pool, new)
            h.tiles[k] = new
            pool._give_back(old)
    else:
        raise JournalReplayError(
            f"unknown pool journal event {ev.kind!r}", kind=ev.kind
        )


def replay_pool(journal: Journal, seed: int = 0, **pool_kwargs) -> "TilePool":
    """Rebuild a :class:`TilePool` from its journal.

    Without a snapshot base the journal must open with geometry-bearing
    events recorded by a journaled pool; pass ``pool_kwargs``
    (``n_arenas``/``tiles_per_arena``/``policy``/``n_channels``) to seed the
    empty pool in that case.
    """
    from repro.core.arena import TilePool

    if journal.base is not None:
        pool = restore_pool(journal.base, seed=seed)
    else:
        pool = TilePool(seed=seed, **pool_kwargs)
    for ev in journal.events:
        apply_pool_event(pool, ev)
    return pool


def pool_digest(pool: "TilePool") -> str:
    return json.dumps(snapshot_pool(pool), sort_keys=True)


# ---------------------------------------------------------------------------
# PagedKVPool: forced replay of the interleaved tile + slot log
# ---------------------------------------------------------------------------

def replay_kv_pool(journal: Journal, cfg: "KVPoolConfig") -> "PagedKVPool":
    """Rebuild the *bookkeeping* of a :class:`PagedKVPool` (slot map, block
    tables, tile pool) from its journal.  Device KV buffers restore to
    zeros — the journal is an allocator WAL, not a data log; callers that
    need the bytes re-run prefill, exactly like a serving engine recovering
    its cache after a restart.

    The KV pool shares one journal with its inner tile pool, so tile-level
    kinds (``alloc``/``extend``/``free``/``compact``) interleave with
    slot-level kinds (``kv_admit``/``kv_fork``/``kv_append``/``kv_release``)
    in one total order.
    """
    from repro.core.kv_pool import PagedKVPool

    kv = PagedKVPool(cfg)
    pool = kv.pool
    for ev in journal.events:
        d = ev.data
        if ev.kind in ("alloc", "extend", "free", "compact"):
            apply_pool_event(pool, ev)
        elif ev.kind in ("kv_admit", "kv_fork"):
            slot, hid, ntok = int(d["slot"]), int(d["hid"]), int(d["ntok"])
            _need(hid in pool._handles,
                  f"{ev.kind} references dead handle {hid}", hid=hid)
            _need(slot in kv._free_slots,
                  f"{ev.kind} into occupied slot {slot}", slot=slot)
            kv._free_slots.remove(slot)
            kv._seqs[slot] = (pool._handles[hid], ntok)
        elif ev.kind == "kv_append":
            slot = int(d["slot"])
            _need(slot in kv._seqs, f"kv_append to dead slot {slot}", slot=slot)
            h, ntok = kv._seqs[slot]
            kv._seqs[slot] = (h, ntok + 1)
        elif ev.kind == "kv_release":
            slot = int(d["slot"])
            _need(slot in kv._seqs, f"kv_release of dead slot {slot}", slot=slot)
            kv._seqs.pop(slot)
            kv._free_slots.append(slot)
        else:
            raise JournalReplayError(
                f"unknown KV journal event {ev.kind!r}", kind=ev.kind
            )
    return kv


def kv_pool_digest(kv: "PagedKVPool") -> str:
    state = {
        "pool": snapshot_pool(kv.pool),
        "seqs": [[int(slot), int(h.hid), int(ntok)]
                 for slot, (h, ntok) in sorted(kv._seqs.items())],
        "free_slots": sorted(int(s) for s in kv._free_slots),
    }
    return json.dumps(state, sort_keys=True)
