"""Structural invariant audits for the PUMA allocation stack (ISSUE 7).

Each ``check_*`` function walks one layer's bookkeeping and cross-checks the
redundant views against each other — free lists vs. running totals vs. stats
counters vs. live-allocation extents — returning an :class:`InvariantReport`.
They are *read-only* and cheap enough to run after every injected fault in
the chaos suite, which is exactly how the property/chaos tests use them:
inject, audit, continue.

The conservation law for the PUD pool (with fault quarantine):

    preallocated == free + in_use + quarantined

i.e. a region handed to the allocator is always in exactly one of the three
states; a violation means a leak (region vanished) or a double-free / overlap
(region counted twice).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List

import numpy as np

from repro.robustness.errors import InvariantViolation

if TYPE_CHECKING:
    from repro.core.arena import TilePool
    from repro.core.kv_pool import PagedKVPool
    from repro.core.puma import PumaAllocator

__all__ = [
    "InvariantReport",
    "check_allocator",
    "check_tile_pool",
    "check_kv_pool",
    "check_engine",
]


@dataclasses.dataclass
class InvariantReport:
    subject: str
    checked: int = 0
    violations: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def _check(self, cond: bool, msg: str) -> None:
        self.checked += 1
        if not cond:
            self.violations.append(msg)

    def assert_ok(self) -> "InvariantReport":
        if self.violations:
            raise InvariantViolation(
                f"{self.subject}: {len(self.violations)} invariant violation(s): "
                + "; ".join(self.violations[:5]),
                subject=self.subject,
                n_violations=len(self.violations),
            )
        return self


# ---------------------------------------------------------------------------
# PUD-pool allocator (core/puma.py)
# ---------------------------------------------------------------------------

def check_allocator(al: "PumaAllocator") -> InvariantReport:
    """Audit a :class:`~repro.core.puma.PumaAllocator`:

    * free-list / heap / per-channel totals agree;
    * free, in-use, and quarantined region PAs are region-aligned and
      pairwise disjoint (no overlap, no double-free);
    * allocation extents mirror the region lists exactly (the re-mmap view);
    * no free region sits in a blacklisted subarray;
    * conservation: preallocated == free + in_use + quarantined.
    """
    rep = InvariantReport(subject="PumaAllocator")
    rb = al.region_bytes
    ordered = al._ordered

    free_pas: List[int] = []
    for sa, lst in ordered.free.items():
        free_pas.extend(lst)
        rep._check(
            sa not in al._blacklisted or not lst,
            f"blacklisted subarray {sa} still has {len(lst)} free regions",
        )
    rep._check(
        len(free_pas) == ordered.total_free(),
        f"free-list size {len(free_pas)} != running total {ordered.total_free()}",
    )
    rep._check(
        sum(ordered.channel_free()) == ordered.total_free(),
        "per-channel free totals do not sum to the global total",
    )

    in_use: List[int] = []
    for va, regions in al._regions_of.items():
        in_use.extend(regions)
        alloc = al._allocations.get(va)
        rep._check(alloc is not None, f"region list for va {va:#x} has no allocation")
        if alloc is None:
            continue
        # extents are coalesced (PA-adjacent merge), so audit the *mapping*:
        # contiguous VA coverage of the padded size, and region k translating
        # to the k-th region PA.
        covered = 0
        for e in alloc.extents:
            rep._check(
                e.va_off == covered,
                f"va {va:#x}: VA hole or overlap at offset {e.va_off}",
            )
            covered = e.va_off + e.nbytes
        rep._check(
            covered == len(regions) * rb,
            f"va {va:#x}: extents cover {covered} bytes, "
            f"expected {len(regions) * rb}",
        )
        try:
            translates = all(
                alloc.pa_of(i * rb) == pa for i, pa in enumerate(regions)
            )
        except ValueError:  # region list longer than the mapping: corrupt
            translates = False
        rep._check(
            translates,
            f"va {va:#x}: extent translation diverges from the region list",
        )
        rep._check(
            len(regions) * rb >= alloc.size,
            f"va {va:#x}: {len(regions)} regions cannot back {alloc.size} bytes",
        )
    rep._check(
        len(al._allocations) == len(al._regions_of),
        "allocation hashmap and region map disagree on live allocations",
    )
    rep._check(
        al.stats.live_allocations == len(al._allocations),
        f"stats.live_allocations={al.stats.live_allocations} != "
        f"{len(al._allocations)} live entries",
    )
    rep._check(
        al.stats.regions_in_use == len(in_use),
        f"stats.regions_in_use={al.stats.regions_in_use} != {len(in_use)}",
    )
    rep._check(
        int(al._used_per_channel.sum()) == len(in_use),
        "per-channel used counters do not sum to the in-use region count",
    )

    quarantined = list(al._quarantined)
    everything = free_pas + in_use + quarantined
    rep._check(
        all(pa % rb == 0 for pa in everything),
        "region PA not region-aligned",
    )
    rep._check(
        len(set(everything)) == len(everything),
        "region PA appears in more than one state (overlap / double-count)",
    )
    rep._check(
        al.stats.quarantined_regions == len(quarantined),
        f"stats.quarantined_regions={al.stats.quarantined_regions} != "
        f"{len(quarantined)}",
    )
    rep._check(
        al.stats.preallocated_regions
        == len(free_pas) + len(in_use) + len(quarantined),
        f"conservation broken: preallocated={al.stats.preallocated_regions} != "
        f"free={len(free_pas)} + in_use={len(in_use)} + "
        f"quarantined={len(quarantined)}",
    )
    # live regions must not remain on blacklisted subarrays (remap completeness)
    if al._blacklisted and in_use:
        sas = al.amap.region_subarrays(np.asarray(in_use, np.int64))
        bl = np.fromiter(al._blacklisted, dtype=np.int64)
        rep._check(
            not np.isin(sas, bl).any(),
            "live region still mapped to a blacklisted subarray",
        )
    return rep


# ---------------------------------------------------------------------------
# Device tile pool (core/arena.py)
# ---------------------------------------------------------------------------

def check_tile_pool(pool: "TilePool") -> InvariantReport:
    """Audit a :class:`~repro.core.arena.TilePool`: free lists sorted and
    in-range, live handles disjoint from the free set and from each other,
    and conservation free + used == total."""
    rep = InvariantReport(subject="TilePool")
    tpa = pool.tiles_per_arena

    free_tiles: List[int] = []
    for a, lst in enumerate(pool._free):
        rep._check(
            all(0 <= s < tpa for s in lst),
            f"arena {a}: free slot out of range",
        )
        rep._check(
            all(x < y for x, y in zip(lst, lst[1:])),
            f"arena {a}: free list not strictly sorted (duplicate slot?)",
        )
        free_tiles.extend(a * tpa + s for s in lst)

    used_tiles: List[int] = []
    for hid, h in pool._handles.items():
        rep._check(h.hid == hid, f"handle {hid}: hid mismatch")
        rep._check(
            all(0 <= t < pool.total_tiles for t in h.tiles),
            f"handle {hid}: tile index out of range",
        )
        used_tiles.extend(h.tiles)

    rep._check(
        len(set(used_tiles)) == len(used_tiles),
        "tile owned by two handles (overlap) or twice by one",
    )
    rep._check(
        not set(free_tiles) & set(used_tiles),
        "tile simultaneously free and owned by a live handle",
    )
    rep._check(
        len(free_tiles) + len(used_tiles) == pool.total_tiles,
        f"conservation broken: free={len(free_tiles)} + used={len(used_tiles)} "
        f"!= total={pool.total_tiles} (leaked tiles)",
    )
    return rep


# ---------------------------------------------------------------------------
# Paged KV pool + serving engine (core/kv_pool.py, serve/engine.py)
# ---------------------------------------------------------------------------

def check_kv_pool(kv: "PagedKVPool") -> InvariantReport:
    """Audit a :class:`~repro.core.kv_pool.PagedKVPool`: the underlying tile
    pool plus slot bookkeeping and block tables."""
    rep = check_tile_pool(kv.pool)
    rep.subject = "PagedKVPool"
    cfg = kv.cfg

    slots = set(kv._seqs)
    free_slots = list(kv._free_slots)
    rep._check(
        len(set(free_slots)) == len(free_slots), "duplicate free seq slot"
    )
    rep._check(
        not slots & set(free_slots), "seq slot both live and free"
    )
    rep._check(
        len(slots) + len(free_slots) == cfg.max_seqs,
        f"slot conservation broken: live={len(slots)} + free={len(free_slots)} "
        f"!= max_seqs={cfg.max_seqs}",
    )
    for slot, (h, ntok) in kv._seqs.items():
        rep._check(
            h.hid in kv.pool._handles,
            f"slot {slot}: handle {h.hid} not live in the tile pool",
        )
        rep._check(
            0 <= ntok <= len(h.tiles) * cfg.block_size,
            f"slot {slot}: {ntok} tokens exceed {len(h.tiles)} blocks",
        )
    tbl = kv.block_table()
    rep._check(
        int(tbl.max(initial=-1)) < cfg.num_blocks,
        "block table references a block beyond the pool",
    )
    return rep


def check_engine(eng) -> InvariantReport:
    """Audit a :class:`~repro.serve.engine.ServeEngine`: the KV pool plus
    request accounting — every submitted request is in exactly one of
    queued / live / done / rejected / cancelled (zero silent drops).

    Requests injected directly into ``eng.live`` (bypassing ``submit``, as
    the fork test does) break the submitted-count identity; use this checker
    on engines driven through the public API.
    """
    rep = check_kv_pool(eng.pool)
    rep.subject = "ServeEngine"

    accounted = (
        len(eng.queue) + len(eng.live) + len(eng.done)
        + len(eng.rejected) + len(eng.cancelled)
    )
    rep._check(
        eng.submitted == accounted,
        f"request accounting broken: submitted={eng.submitted} != "
        f"queued={len(eng.queue)} + live={len(eng.live)} + done={len(eng.done)} "
        f"+ rejected={len(eng.rejected)} + cancelled={len(eng.cancelled)}",
    )
    for slot, req in eng.live.items():
        rep._check(req.slot == slot, f"rid {req.rid}: slot field diverges")
        rep._check(
            req.status == "running", f"rid {req.rid}: live but {req.status!r}"
        )
        rep._check(
            slot in eng.pool._seqs,
            f"rid {req.rid}: live without KV blocks (slot {slot})",
        )
    for req in eng.queue:
        rep._check(
            req.status == "queued", f"rid {req.rid}: queued but {req.status!r}"
        )
        rep._check(req.slot is None, f"rid {req.rid}: queued but holds a slot")
    for name, lst, want in (
        ("done", eng.done, "done"),
        ("rejected", eng.rejected, "rejected"),
        ("cancelled", eng.cancelled, "cancelled"),
    ):
        for req in lst:
            rep._check(
                req.status == want, f"rid {req.rid}: in {name} but {req.status!r}"
            )
            if want != "done":
                rep._check(
                    req.error is not None,
                    f"rid {req.rid}: {name} without a recorded error (silent drop)",
                )
    return rep
