"""Deterministic, seeded fault injection for the PUMA stack (ISSUE 7).

One :class:`FaultInjector` (configured by a :class:`FaultPlan`) threads
through every layer that can fail:

* ``PhysicalMemory.take_huge``   — huge-page-pool exhaustion (transient
  denials at ``huge_exhaust_rate``), modelling a contended boot reservation;
* ``PumaAllocator.pim_alloc*``   — fragmented-arena allocation misses at
  ``alloc_miss_rate`` (the ordered array transiently cannot produce a
  region, as under concurrent churn);
* ``TilePool.alloc``/``extend``  — the same transient miss on the
  device-side tile pool, which is what drives the serving engine's
  preemption path;
* ``pud.simulate_op``/``execute_op`` — RowClone copy failures at a per-row
  ``rowclone_fail_rate``; a ``permanent_fraction`` of those are permanent
  subarray faults, which blacklist the subarray (the allocator then
  quarantines and remaps its rows);
* ``ChannelController`` — per-channel controller stalls (refresh storms,
  thermal throttle) at ``channel_stall_rate`` x ``channel_stall_ns``.

Determinism: every decision comes from one ``random.Random(seed)`` stream,
so a fixed seed plus a fixed call sequence reproduces the exact fault
pattern — the chaos suite and CI gate rely on this.

The injector only *decides*; each hook site owns its failure semantics
(raise a typed error, return None, add latency).  ``FaultStats`` counts
every injected event so benchmarks can report the injected load next to
the observed degradation.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Iterable, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = ["FaultPlan", "FaultStats", "FaultInjector", "injected_alloc_miss"]


def injected_alloc_miss(
    injector: Optional["FaultInjector"], stats, failed_attr: str = "failed"
) -> bool:
    """Shared transient-miss hook for pool allocators.

    Consults ``injector.alloc_missed()`` and, when the miss fires, bumps the
    caller's failure counter (``failed_attr`` — ``failed`` on
    :class:`~repro.core.arena.PoolStats`, ``failed_allocs`` on
    :class:`~repro.core.puma.PumaStats`) plus its ``injected_misses``.
    ``PumaAllocator`` and ``TilePool`` both delegate their ``_injected_miss``
    to this one helper so the miss semantics cannot drift apart.
    """
    if injector is None or not injector.alloc_missed():
        return False
    setattr(stats, failed_attr, getattr(stats, failed_attr) + 1)
    stats.injected_misses += 1
    return True


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Injection knobs.  All rates are probabilities in [0, 1]; the default
    plan injects nothing (an injector with a default plan is a no-op)."""

    seed: int = 0
    #: P[one RowClone row op faults] — the paper-scale documented rate for
    #: the chaos suite is 1e-3.
    rowclone_fail_rate: float = 0.0
    #: fraction of RowClone faults that are *permanent* subarray failures
    #: (blacklist + remap) rather than transient (CPU retry only).
    permanent_fraction: float = 0.0
    #: P[a take_huge call is denied] — huge-page-pool exhaustion.
    huge_exhaust_rate: float = 0.0
    #: P[a pool allocation transiently misses] (PUMA ordered array and the
    #: serving TilePool both consult this).
    alloc_miss_rate: float = 0.0
    #: P[a dispatched channel burst hits an injected stall].
    channel_stall_rate: float = 0.0
    #: stall duration added to the channel's busy frontier when it fires.
    channel_stall_ns: float = 500.0
    #: subarrays dead from t=0 (manufacturing faults): never allocated from,
    #: never PUD-executed in.
    blacklist_subarrays: Tuple[int, ...] = ()

    def __post_init__(self):
        for f in ("rowclone_fail_rate", "permanent_fraction",
                  "huge_exhaust_rate", "alloc_miss_rate",
                  "channel_stall_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f}={v} outside [0, 1]")
        if self.channel_stall_ns < 0:
            raise ValueError(f"channel_stall_ns={self.channel_stall_ns} < 0")


@dataclasses.dataclass
class FaultStats:
    rowclone_faults: int = 0
    permanent_faults: int = 0
    huge_denials: int = 0
    alloc_misses: int = 0
    channel_stalls: int = 0
    stall_ns: float = 0.0

    def total_injected(self) -> int:
        return (self.rowclone_faults + self.huge_denials
                + self.alloc_misses + self.channel_stalls)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class FaultInjector:
    """Seeded decision source for every fault hook.

    One injector instance is shared across the layers of one simulated
    machine so the blacklist and the statistics are globally consistent.
    """

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan or FaultPlan()
        self.rng = random.Random(self.plan.seed)
        self.stats = FaultStats()
        self.blacklist: Set[int] = set(self.plan.blacklist_subarrays)

    # -- huge-page pool -------------------------------------------------------
    def huge_denied(self) -> bool:
        """True when this ``take_huge`` call should fail transiently."""
        p = self.plan.huge_exhaust_rate
        if p and self.rng.random() < p:
            self.stats.huge_denials += 1
            return True
        return False

    # -- allocator misses -----------------------------------------------------
    def alloc_missed(self) -> bool:
        """True when this pool allocation should transiently miss."""
        p = self.plan.alloc_miss_rate
        if p and self.rng.random() < p:
            self.stats.alloc_misses += 1
            return True
        return False

    # -- RowClone row faults --------------------------------------------------
    def rowclone_faults(self, subarrays: Sequence[int]) -> np.ndarray:
        """Per-row fault mask for one op's PUD rows (global subarray IDs).

        Permanent faults additionally move the row's subarray onto the
        blacklist; the caller is responsible for quarantining/remapping
        (see :meth:`PumaAllocator.blacklist_subarray`).
        """
        n = len(subarrays)
        mask = np.zeros(n, dtype=bool)
        p = self.plan.rowclone_fail_rate
        if not p or n == 0:
            return mask
        for i in range(n):
            if self.rng.random() < p:
                mask[i] = True
                self.stats.rowclone_faults += 1
                if (self.plan.permanent_fraction
                        and self.rng.random() < self.plan.permanent_fraction):
                    sa = int(subarrays[i])
                    if sa >= 0 and sa not in self.blacklist:
                        self.blacklist.add(sa)
                        self.stats.permanent_faults += 1
        return mask

    # -- blacklist ------------------------------------------------------------
    def is_blacklisted(self, subarray: int) -> bool:
        return subarray in self.blacklist

    def blacklisted_mask(self, subarrays: np.ndarray) -> np.ndarray:
        """Boolean mask of blacklisted entries (vectorized)."""
        sas = np.asarray(subarrays, dtype=np.int64)
        if not self.blacklist:
            return np.zeros(sas.shape, dtype=bool)
        bl = np.fromiter(self.blacklist, dtype=np.int64)
        return np.isin(sas, bl)

    def new_permanent_faults(self, known: Iterable[int]) -> Set[int]:
        """Blacklisted subarrays the caller has not yet quarantined."""
        return self.blacklist - set(known)

    # -- controller stalls ----------------------------------------------------
    def stall_ns(self) -> float:
        """Injected stall for one channel burst (0.0 = no stall)."""
        p = self.plan.channel_stall_rate
        if p and self.rng.random() < p:
            self.stats.channel_stalls += 1
            self.stats.stall_ns += self.plan.channel_stall_ns
            return self.plan.channel_stall_ns
        return 0.0
