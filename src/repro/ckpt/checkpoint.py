"""Sharded, atomic, resumable checkpoints (orbax-free, npz-per-leaf).

Layout::

    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, mesh, "complete"
        leaf_00000.npy ... # one file per pytree leaf

Protocol:

* **atomic** — written to ``step_X.tmp`` then renamed; the manifest's
  ``complete: true`` flag is written last, so a crash mid-write can never be
  mistaken for a valid checkpoint.
* **resume** — ``latest_step`` scans for the highest complete step.
* **elastic** — leaves are saved *unsharded* (canonical logical layout), so a
  restart may use a different mesh/host count; ``restore`` re-shards via the
  shardings you pass it.  At 1000-node scale the same manifest format points
  at per-shard files instead — the protocol (atomicity, completeness flag,
  canonical logical layout) is the part that matters.
* **GC** — ``keep`` most recent checkpoints survive.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any, keep: int = 3) -> str:
    """Save a pytree checkpoint; returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _leaf_paths(tree)
    meta = {
        "step": step,
        "complete": False,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        meta["leaves"].append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    # completeness flag last, then atomic rename
    meta["complete"] = True
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(path, keep)
    return final


def _gc(path: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(path)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(path, d))


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    best = None
    for d in os.listdir(path):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        mf = os.path.join(path, d, "manifest.json")
        try:
            with open(mf) as f:
                meta = json.load(f)
            if meta.get("complete"):
                best = max(best or -1, meta["step"])
        except (OSError, json.JSONDecodeError):
            continue
    return best


def restore(path: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally re-shard."""
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    assert meta["complete"], f"checkpoint {d} incomplete"
    leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == meta["n_leaves"], (
        f"leaf count mismatch: have {len(leaves)}, ckpt {meta['n_leaves']}"
    )
    out = []
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        assert tuple(arr.shape) == tuple(ref.shape), (i, arr.shape, ref.shape)
        if sh is not None:
            out.append(jax.device_put(arr.astype(ref.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr.astype(ref.dtype)))
    return jax.tree.unflatten(treedef, out)
