"""Models of the baseline memory allocators the paper evaluates against.

The paper's §1 study: ``malloc`` and ``posix_memalign`` give virtually
contiguous but *physically scattered* pages, so 0 % of PUD operations can
execute in DRAM; huge-page-backed allocation is physically contiguous per
2 MB page but coarse, so multi-operand PUD ops co-locate only opportunistically
(<= ~60 % at 32 Kb+ allocation sizes).

Everything is modeled at the level the OS sees: a ``PhysicalMemory`` with
4 KB base pages and 2 MB huge pages, boot-time fragmentation, and allocators
that build VA->PA page tables.  ``Allocation`` is the common currency shared
with :mod:`repro.core.puma` and consumed by :mod:`repro.core.pud`; its
extent list is normalized (sorted + physically-adjacent extents coalesced)
at construction so translation is O(log E) bisect and bulk consumers walk
whole runs via :meth:`Allocation.runs` instead of probing byte-by-byte.
"""
from __future__ import annotations

import dataclasses
import random
from bisect import bisect_right
from typing import TYPE_CHECKING, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.dram import AddressMap
from repro.robustness.errors import (
    BasePageExhausted,
    HugePageExhausted,
    TranslationError,
)

if TYPE_CHECKING:  # avoid importing the injector at runtime: decide-only dep
    from repro.robustness.faults import FaultInjector

PAGE = 4096
HUGE_PAGE = 2 * 1024 * 1024

__all__ = [
    "PAGE",
    "HUGE_PAGE",
    "Extent",
    "Allocation",
    "PhysicalMemory",
    "MallocModel",
    "PosixMemalignModel",
    "HugePageModel",
]


@dataclasses.dataclass(frozen=True)
class Extent:
    """A physically contiguous run backing part of an allocation."""

    va_off: int   # offset within the allocation's VA range
    pa: int       # physical base address
    nbytes: int


@dataclasses.dataclass
class Allocation:
    """VA-contiguous allocation with its VA->PA mapping.

    Extents are normalized at construction: sorted by ``va_off`` and
    *coalesced* — VA-adjacent extents that are also PA-adjacent merge into
    one.  After coalescing every extent is a maximal physically contiguous
    run, so translation is a single ``bisect`` over the cached ``va_off``
    array instead of a linear scan, and :meth:`runs` hands callers whole
    (pa, nbytes) runs so nobody ever probes byte-by-byte.
    """

    va: int
    size: int
    extents: List[Extent]          # sorted by va_off, covering [0, size_padded)
    allocator: str

    def __post_init__(self):
        exts = sorted(self.extents, key=lambda e: e.va_off)
        merged: List[Extent] = []
        for e in exts:
            if merged:
                m = merged[-1]
                if m.va_off + m.nbytes == e.va_off and m.pa + m.nbytes == e.pa:
                    merged[-1] = Extent(m.va_off, m.pa, m.nbytes + e.nbytes)
                    continue
            merged.append(e)
        self.extents = merged
        # Parallel plain-int lists: bisect + index, no attribute chasing.
        self._va_offs: List[int] = [e.va_off for e in merged]
        self._va_ends: List[int] = [e.va_off + e.nbytes for e in merged]
        self._pas: List[int] = [e.pa for e in merged]
        self._row_sa_cache: Dict[int, Tuple[object, np.ndarray]] = {}

    def pa_of(self, va_off: int) -> int:
        """Translate an offset inside the allocation to a physical address.

        Raises :class:`TranslationError` (a ``ValueError``) on unmapped
        offsets — including any offset into a zero-size/zero-extent
        allocation.
        """
        i = bisect_right(self._va_offs, va_off) - 1
        if i >= 0 and va_off < self._va_ends[i]:
            return self._pas[i] + (va_off - self._va_offs[i])
        raise TranslationError(
            f"offset {va_off} not mapped (size={self.size})",
            va_off=va_off, size=self.size, allocator=self.allocator,
        )

    def contiguous_run(self, va_off: int, nbytes: int) -> Optional[int]:
        """PA base if [va_off, va_off+nbytes) is one physically contiguous run.

        An unmapped *start* offset (negative, in a hole, beyond the mapping,
        or any offset of a zero-extent allocation) raises
        :class:`TranslationError`; a run whose *end* merely overflows the
        mapping returns None, like any other non-contiguous request.
        """
        i = bisect_right(self._va_offs, va_off) - 1
        if i < 0 or not self.extents or va_off >= self._va_ends[i]:
            raise TranslationError(
                f"offset {va_off} not mapped (size={self.size})",
                va_off=va_off, size=self.size, allocator=self.allocator,
            )
        if va_off + nbytes > self._va_ends[-1]:
            return None
        # extents are coalesced, so a contiguous run cannot span two of them
        if va_off + nbytes <= self._va_ends[i]:
            return self._pas[i] + (va_off - self._va_offs[i])
        return None

    def runs(self, va_off: int, nbytes: int) -> Iterator[Tuple[int, int]]:
        """Yield maximal physically contiguous ``(pa, nbytes)`` runs covering
        ``[va_off, va_off + nbytes)``, in VA order."""
        end = va_off + nbytes
        i = bisect_right(self._va_offs, va_off) - 1
        cur = va_off
        while cur < end:
            if i < 0 or i >= len(self.extents) or not (
                self._va_offs[i] <= cur < self._va_ends[i]
            ):
                raise TranslationError(
                    f"offset {cur} not mapped (size={self.size})",
                    va_off=cur, size=self.size, allocator=self.allocator,
                )
            n = min(end, self._va_ends[i]) - cur
            yield self._pas[i] + (cur - self._va_offs[i]), n
            cur += n
            i += 1


class PhysicalMemory:
    """Free-page bookkeeping for a booted system.

    ``occupancy`` simulates a long-running machine: that fraction of base
    pages is already in use (scattered), so fresh 4 KB allocations come from
    a shuffled free list — the physical-discontiguity source the paper
    identifies.  Huge pages are reserved at boot from the *low, unfragmented*
    end of memory (standard hugetlbfs behaviour), so they are individually
    contiguous and mostly mutually adjacent.
    """

    def __init__(
        self,
        amap: AddressMap,
        *,
        occupancy: float = 0.35,
        n_huge_pages: int = 512,
        huge_scatter: float = 0.15,
        seed: int = 0,
        injector: Optional["FaultInjector"] = None,
    ):
        self.amap = amap
        self.rng = random.Random(seed)
        #: fault injector consulted on every huge-page grab (transient
        #: exhaustion); None = never inject.
        self.injector = injector
        total = amap.total_bytes
        self.n_huge = n_huge_pages
        huge_bytes = n_huge_pages * HUGE_PAGE
        if huge_bytes > total // 2:
            raise ValueError("huge page pool exceeds half of memory")

        # Huge-page pool: boot-time reservation, mostly sequential.  A
        # fraction `huge_scatter` of pages is displaced to random slots to
        # model a pool grown after boot / CMA fragmentation.
        slots = list(range(total // HUGE_PAGE))
        seq = slots[: n_huge_pages]
        n_scattered = int(n_huge_pages * huge_scatter)
        if n_scattered:
            displaced = self.rng.sample(range(n_huge_pages), n_scattered)
            far = self.rng.sample(slots[n_huge_pages:], n_scattered)
            for i, slot in zip(displaced, far):
                seq[i] = slot
        self.free_huge: List[int] = [s * HUGE_PAGE for s in seq]  # FIFO order

        # Base pages in the non-huge region: a long-running system hands out
        # physically scattered frames.  Drawing uniformly at random (with a
        # used-set) is distributionally the same as pre-shuffling the whole
        # free list but O(1) per page instead of O(total/4K) at boot.
        self._base_lo = (n_huge_pages * HUGE_PAGE) // PAGE
        self._base_hi = total // PAGE
        n_base = self._base_hi - self._base_lo
        self._free_budget = int(n_base * (1.0 - occupancy))
        self._used: set = set()

    # -- base 4 KB pages ----------------------------------------------------
    def take_pages(self, n: int) -> List[int]:
        if n > self._free_budget:
            raise BasePageExhausted(
                f"out of base pages ({n} wanted)",
                wanted=n, free=self._free_budget,
            )
        out: List[int] = []
        while len(out) < n:
            p = self.rng.randrange(self._base_lo, self._base_hi)
            if p in self._used:
                continue
            self._used.add(p)
            out.append(p * PAGE)
        self._free_budget -= n
        return out

    def release_pages(self, pas: List[int]) -> None:
        for pa in pas:
            self._used.discard(pa // PAGE)
        self._free_budget += len(pas)

    # -- 2 MB huge pages ----------------------------------------------------
    def take_huge(self, n: int) -> List[int]:
        if n > len(self.free_huge):
            raise HugePageExhausted(
                f"out of huge pages ({n} wanted)",
                wanted=n, free=len(self.free_huge),
            )
        if n and self.injector is not None and self.injector.huge_denied():
            # transient denial (reservation contention): the pool is not
            # actually drained — retry-with-backoff may succeed.
            raise HugePageExhausted(
                f"huge page grab denied ({n} wanted)", injected=True,
                wanted=n, free=len(self.free_huge),
            )
        out, self.free_huge = self.free_huge[:n], self.free_huge[n:]
        return out

    def release_huge(self, pas: List[int]) -> None:
        self.free_huge.extend(pas)


class _VaSpace:
    """Trivial bump allocator for virtual addresses (never reused)."""

    def __init__(self, base: int = 0x7F00_0000_0000):
        self._next = base

    def take(self, size: int, align: int) -> int:
        va = -(-self._next // align) * align
        self._next = va + size
        return va


class MallocModel:
    """glibc-style malloc: small requests packed into a heap, large requests
    mmap'd.  Either way the backing 4 KB pages are physically scattered."""

    name = "malloc"
    MMAP_THRESHOLD = 128 * 1024
    HEAP_ALIGN = 16

    def __init__(self, mem: PhysicalMemory):
        self.mem = mem
        self.va = _VaSpace(0x5555_0000_0000)
        self._heap_va: Optional[int] = None
        self._heap_off = 0
        self._heap_extents: List[Extent] = []

    def _grow_heap(self, need: int) -> None:
        npages = -(-need // PAGE) + 8
        pas = self.mem.take_pages(npages)
        if self._heap_va is None:
            self._heap_va = self.va.take(1 << 30, PAGE)  # reserve a VA window
        off = len(self._heap_extents) * PAGE
        for i, pa in enumerate(pas):
            self._heap_extents.append(Extent(off + i * PAGE, pa, PAGE))

    def alloc(self, size: int) -> Allocation:
        if size >= self.MMAP_THRESHOLD:
            npages = -(-size // PAGE)
            pas = self.mem.take_pages(npages)
            va = self.va.take(npages * PAGE, PAGE)
            extents = [Extent(i * PAGE, pa, PAGE) for i, pa in enumerate(pas)]
            return Allocation(va, size, extents, self.name)
        # heap path: bump pointer at 16-byte alignment
        off = -(-self._heap_off // self.HEAP_ALIGN) * self.HEAP_ALIGN
        end = off + size
        mapped = len(self._heap_extents) * PAGE
        if end > mapped:
            self._grow_heap(end - mapped)
        self._heap_off = end
        # slice the heap extents covering [off, end)
        extents = []
        for e in self._heap_extents:
            if e.va_off + e.nbytes <= off or e.va_off >= end:
                continue
            start = max(e.va_off, off)
            stop = min(e.va_off + e.nbytes, end)
            extents.append(
                Extent(start - off, e.pa + (start - e.va_off), stop - start)
            )
        return Allocation(self._heap_va + off, size, extents, self.name)


class PosixMemalignModel(MallocModel):
    """posix_memalign: virtually aligned, still physically scattered (§1)."""

    name = "posix_memalign"

    def __init__(self, mem: PhysicalMemory, alignment: int = 8192):
        super().__init__(mem)
        self.alignment = alignment

    def alloc(self, size: int) -> Allocation:
        npages = -(-size // PAGE)
        pas = self.mem.take_pages(npages)
        va = self.va.take(npages * PAGE, max(self.alignment, PAGE))
        extents = [Extent(i * PAGE, pa, PAGE) for i, pa in enumerate(pas)]
        return Allocation(va, size, extents, self.name)


class HugePageModel:
    """Huge-page-backed allocation, the paper's strongest baseline.

    Two modes:

    * ``mmap`` (default — what the paper describes: each operand is its own
      "huge page allocation"): every request maps fresh whole huge pages.
      Rows are perfectly aligned and physically contiguous, but since a
      2 MB page spans multiple 1 MB subarrays, *which* subarray row *k* of
      each operand occupies depends on which huge page the pool handed out —
      multi-operand co-location is opportunistic (paper: "it is likely that
      such operands will reside in different DRAM subarrays").

    * ``heap``: a libhugetlbfs-style morecore packs requests into shared
      huge pages with power-of-two alignment capped at the base-page size —
      small requests additionally lose row alignment.
    """

    name = "hugepage"

    def __init__(self, mem: PhysicalMemory, mode: str = "mmap"):
        assert mode in ("mmap", "heap"), mode
        self.mem = mem
        self.mode = mode
        self.name = f"hugepage-{mode}"
        self.va = _VaSpace(0x2AAA_0000_0000)
        self._cur_pa: Optional[int] = None
        self._cur_off = 0

    def _alignment_for(self, size: int) -> int:
        a = 1 << (size - 1).bit_length() if size > 1 else 1
        return max(16, min(a, PAGE))

    def alloc(self, size: int) -> Allocation:
        if self.mode == "heap":
            align = self._alignment_for(size)
            if self._cur_pa is not None:
                off = -(-self._cur_off // align) * align
                if off + size <= HUGE_PAGE:
                    self._cur_off = off + size
                    va = self.va.take(size, align)
                    return Allocation(
                        va, size, [Extent(0, self._cur_pa + off, size)], self.name
                    )
        # fresh huge page(s): one mmap per request
        n = -(-size // HUGE_PAGE)
        pas = self.mem.take_huge(n)
        va = self.va.take(n * HUGE_PAGE, HUGE_PAGE)
        if self.mode == "heap":
            # morecore keeps packing pages: this allocation only owns
            # [0, size) — the remainder belongs to future requests.
            extents = []
            voff = 0
            for pa in pas:
                n_here = min(HUGE_PAGE, size - voff)
                extents.append(Extent(voff, pa, n_here))
                voff += n_here
            if n == 1:
                self._cur_pa, self._cur_off = pas[0], size
            else:
                self._cur_pa, self._cur_off = None, 0
            return Allocation(va, size, extents, self.name)
        extents = []
        voff = 0
        for pa in pas:
            extents.append(Extent(voff, pa, HUGE_PAGE))
            voff += HUGE_PAGE
        return Allocation(va, size, extents, self.name)
