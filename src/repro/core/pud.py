"""PUD executability + timing model (RowClone / Ambit substrate, paper §3).

The evaluated substrate executes, *in DRAM*:

* ``zero``  — RowClone zero-init  (copy from a reserved all-zeros row),
* ``copy``  — RowClone FPM intra-subarray row copy,
* ``and/or/not`` — Ambit triple-row-activation Boolean ops,

and each operation proceeds row by row.  A row-granular op is PUD-executable
iff **every operand's row** (i) is physically contiguous, (ii) starts at a
rank-row boundary, and (iii) all operand rows share one global subarray —
exactly the paper's criterion ("source and destination operands are
contiguous in physical memory and DRAM-row-aligned", same subarray).
Rows that fail fall back to the CPU, as does the sub-row tail of every
allocation.

Timing constants approximate DDR3/4 values used by RowClone [104] and
Ambit [101]: an AAP (ACTIVATE-ACTIVATE-PRECHARGE) command sequence costs
~tRAS+tRP ≈ 90 ns and touches a full 8 KB rank-row.  The CPU fallback prices
a streaming read/write through the memory hierarchy.  Absolute numbers only
set the scale; the paper's Figure 2 normalizes to the malloc baseline, and
so do we.

Planning fast path
------------------

``plan_rows`` no longer probes each logical row with scalar
``contiguous_run``/``region_subarray`` calls.  Each operand's per-row global
subarray (or -1 where the row is not PUD-capable) is computed as one numpy
array — a ``searchsorted`` over the allocation's coalesced extents plus the
batch decode from :mod:`repro.core.dram` — and memoized on the
``Allocation`` (the mapping is immutable after construction, so the cache
lives as long as the allocation; freeing drops the allocation and the table
with it).  Executability is then a vectorized equality across operand
tables.  ``execute_op`` walks :meth:`Allocation.runs` so every physically
contiguous run moves as one slice instead of byte-by-byte ``pa_of`` probing.
Property tests pin both fast paths to the original scalar semantics.

Channel-partitioned execution
-----------------------------

The substrate is channel-parallel: every channel has its own memory
controller (:mod:`repro.core.controller`) and PUD rows living in different
channels execute concurrently.  :class:`RowPlan` therefore also records the
*global subarray per row* (``subarrays``; the owning channel is
``gsa % channels``), and:

* ``simulate_op`` partitions the PUD rows by owning channel (one
  ``bincount``) and prices the in-DRAM part as ``max`` over per-channel row
  counts x per-row AAP cost instead of a serial sum — a RowClone copy
  striped over 8 channels finishes ~8x faster.  With a
  :class:`~repro.core.controller.DramController` passed in, the op is
  additionally queued on the controllers' ``busy_until`` frontiers, so
  back-to-back ops contending for one channel visibly serialize and mode
  switches (PUD interleaved with normal traffic) are charged.
* ``execute_op`` walks the row list channel by channel — the functional
  result is unchanged (rows are disjoint), but the dispatch order mirrors
  the per-channel command streams and, with a controller, records the same
  timing.

At ``channels=1`` both collapse bit-for-bit to the original single-channel
serial model (``max`` over one channel *is* the total row count); property
tests in ``tests/test_pud.py`` pin that equivalence under both interleave
schemes.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

import numpy as np

from repro.core.allocators import Allocation
from repro.core.controller import DramController, channel_row_counts
from repro.core.dram import AddressMap

if TYPE_CHECKING:
    from repro.robustness.faults import FaultInjector

__all__ = [
    "OpKind",
    "PudCostModel",
    "RowPlan",
    "row_subarray_table",
    "plan_rows",
    "simulate_op",
    "execute_op",
    "MigrationCost",
    "price_migration",
]


OpKind = str  # "zero" | "copy" | "and" | "or" | "not" | "mac"

#: operands (incl. destination) per op.  ``mac`` is the arithmetic
#: extension toward MIMDRAM/Proteus-style substrates (ROADMAP Tracegen
#: item): a decode-time multiply-accumulate over a weight row into a
#: co-located accumulator row — 2 operands (weight, accumulator), the
#: scalar input vector is broadcast through the mat drivers.
N_OPERANDS: Dict[str, int] = {
    "zero": 1, "copy": 2, "and": 3, "or": 3, "not": 2, "mac": 2,
}

#: AAP sequences per row for each PUD op (RowClone/Ambit command counts;
#: ``mac`` approximates MIMDRAM's bit-serial popcount-accumulate ladder —
#: several majority/copy rounds per element group, so 8 AAPs per row).
PUD_AAPS: Dict[str, int] = {
    "zero": 1, "copy": 2, "and": 4, "or": 4, "not": 3, "mac": 8,
}


@dataclasses.dataclass(frozen=True)
class PudCostModel:
    aap_ns: float = 90.0            # ACT(tRAS 35ns) + ACT + PRE(tRP 15ns) ≈ 90ns
    pud_issue_ns: float = 20.0      # memory-controller command overhead / row
    cpu_bw_gbs: float = 10.0        # streaming CPU bandwidth (read xor write)
    cpu_op_overhead_ns: float = 250.0   # call + loop setup per operation
    cpu_row_touch_ns: float = 40.0      # per-row TLB/prefetch restart on the
                                        # fallback path (data pulled to CPU)

    def pud_row_ns(self, op: OpKind) -> float:
        return PUD_AAPS[op] * self.aap_ns + self.pud_issue_ns

    def cpu_bytes_moved(self, op: OpKind, nbytes: int) -> int:
        # zero: write N; copy: read N + write N; and/or: 2 reads + 1 write;
        # not: read + write; mac: stream the weights + read-modify-write the
        # (vector-sized, cache-resident) accumulator ≈ read N + write N.
        streams = {
            "zero": 1, "copy": 2, "and": 3, "or": 3, "not": 2, "mac": 2,
        }[op]
        return streams * nbytes

    def cpu_ns(self, op: OpKind, nbytes: int, nrows: int = 1) -> float:
        # Unit identity, made explicit: 1 GB/s = 1e9 B / 1e9 ns = exactly
        # 1 byte/ns, so a bandwidth of ``cpu_bw_gbs`` GB/s moves
        # ``cpu_bw_gbs`` bytes per nanosecond.  (Not a coincidence of the
        # default value — the 1e9s cancel for any parameter setting.)
        bytes_per_ns = self.cpu_bw_gbs
        move = self.cpu_bytes_moved(op, nbytes) / bytes_per_ns
        return move + nrows * self.cpu_row_touch_ns


@dataclasses.dataclass
class RowPlan:
    """Per-row execution decision for one op over parallel operands."""

    n_rows: int                 # full rows in the logical buffers
    in_pud: List[bool]          # len n_rows
    tail_bytes: int             # sub-row remainder (always CPU)
    #: global subarray per row (shared by all operands on PUD rows; -1 on
    #: CPU rows).  The owning channel is ``subarrays[r] % channels`` — what
    #: the channel-partitioned executor and the controllers dispatch on.
    subarrays: Optional[np.ndarray] = None
    #: rows that started in DRAM but faulted mid-flight (injected RowClone
    #: failures) and were gracefully re-executed on the CPU.
    faulted_rows: int = 0

    @property
    def pud_fraction(self) -> float:
        if self.n_rows == 0:
            return 0.0
        return sum(self.in_pud) / self.n_rows

    def pud_subarrays(self) -> np.ndarray:
        """Global subarray of each PUD row (non-PUD rows dropped)."""
        if self.subarrays is None:
            return np.empty(0, dtype=np.int64)
        return self.subarrays[self.subarrays >= 0]

    def channel_rows(self, amap: AddressMap) -> np.ndarray:
        """PUD rows per owning channel (len = geometry's channel count)."""
        return channel_row_counts(self.pud_subarrays(), amap)


def _row_subarray(
    alloc: Allocation, row: int, region_bytes: int, amap: AddressMap
) -> Optional[int]:
    """Global subarray of logical row ``row``; None if not PUD-capable.

    Scalar reference path — ``plan_rows`` uses the vectorized
    :func:`row_subarray_table`; property tests assert they agree.
    """
    off = row * region_bytes
    pa = alloc.contiguous_run(off, region_bytes)
    if pa is None or not amap.region_is_aligned(pa):
        return None
    return amap.region_subarray(pa)


def row_subarray_table(alloc: Allocation, amap: AddressMap) -> np.ndarray:
    """Per-row global subarray of ``alloc`` as an int64 array (-1 = not
    PUD-capable), memoized on the allocation.

    Row ``r`` is PUD-capable iff the full region ``[r*region, (r+1)*region)``
    sits inside one coalesced extent (ownership + physical contiguity) at a
    region-aligned physical base; its value is then the region's global
    subarray from the batch decode.
    """
    cached = alloc._row_sa_cache.get(id(amap))
    if cached is not None and cached[0] is amap:
        return cached[1]
    region = amap.region_bytes
    n_rows = -(-alloc.size // region)
    offs = np.arange(n_rows, dtype=np.int64) * region
    va_offs = np.asarray(alloc._va_offs, dtype=np.int64)
    ends = np.asarray(alloc._va_ends, dtype=np.int64)
    pas = np.asarray(alloc._pas, dtype=np.int64)
    idx = np.searchsorted(va_offs, offs, side="right") - 1
    idxc = np.clip(idx, 0, len(va_offs) - 1)
    pa = pas[idxc] + offs - va_offs[idxc]
    ok = (idx >= 0) & (offs + region <= ends[idxc]) & (pa % region == 0)
    table = np.where(ok, amap.region_subarrays(pa), -1)
    alloc._row_sa_cache[id(amap)] = (amap, table)
    return table


def plan_rows(
    op: OpKind,
    operands: Sequence[Allocation],
    amap: AddressMap,
    injector: Optional["FaultInjector"] = None,
) -> RowPlan:
    """Decide, row by row, whether the op can execute in DRAM.

    PUD ops act on whole rows, so the final *partial* logical row can still
    execute in DRAM when every allocator padded the allocation out to a full
    owned region (PUMA and per-mmap huge pages do; heap allocators do not —
    their extents stop at the requested size, and operating on the full row
    would clobber a neighbour).  The row table's full-region contiguity
    check is exactly that ownership test.
    """
    assert len(operands) == N_OPERANDS[op], (op, len(operands))
    size = min(a.size for a in operands)
    region = amap.region_bytes
    n_full, tail = divmod(size, region)
    n_rows = n_full + (1 if tail else 0)
    if n_rows == 0:
        return RowPlan(
            n_rows=0, in_pud=[], tail_bytes=0,
            subarrays=np.empty(0, dtype=np.int64),
        )
    tables = [row_subarray_table(a, amap)[:n_rows] for a in operands]
    ok = tables[0] != -1
    for t in tables[1:]:
        ok = ok & (t == tables[0])
    if injector is not None and injector.blacklist:
        # permanently failed subarrays never execute in DRAM: their rows are
        # planned onto the CPU up front (the driver knows the blacklist).
        ok = ok & ~injector.blacklisted_mask(tables[0])
    in_pud = ok.tolist()
    tail_bytes = 0 if (not tail or in_pud[-1]) else tail
    # on PUD rows every operand shares operand 0's subarray by construction
    subarrays = np.where(ok, tables[0], -1).astype(np.int64)
    return RowPlan(
        n_rows=n_rows, in_pud=in_pud, tail_bytes=tail_bytes,
        subarrays=subarrays,
    )


@dataclasses.dataclass
class SimResult:
    op: OpKind
    size: int
    pud_fraction: float
    t_ns: float          # time with the PUD substrate available
    t_cpu_ns: float      # time if everything ran on the CPU
    #: PUD rows dispatched per channel (len = geometry channel count);
    #: None when the op took the pure-CPU path.
    rows_per_channel: Optional[List[int]] = None
    #: rows whose in-DRAM execution faulted (injected) and were re-run on
    #: the CPU — their wasted AAP time *and* the CPU retry are in ``t_ns``.
    faulted_rows: int = 0

    @property
    def speedup_vs_cpu(self) -> float:
        return self.t_cpu_ns / self.t_ns if self.t_ns > 0 else float("inf")

    @property
    def channel_balance(self) -> float:
        """mean/max PUD rows across channels (1.0 = perfectly striped)."""
        if not self.rows_per_channel:
            return 1.0
        rows = np.asarray(self.rows_per_channel, dtype=np.float64)
        mx = rows.max()
        return float(rows.mean() / mx) if mx > 0 else 1.0


def simulate_op(
    op: OpKind,
    operands: Sequence[Allocation],
    amap: AddressMap,
    model: PudCostModel = PudCostModel(),
    adaptive: bool = True,
    controller: Optional[DramController] = None,
    injector: Optional["FaultInjector"] = None,
    recorder=None,
    label: Optional[str] = None,
) -> SimResult:
    """Price one op.  ``adaptive`` (beyond-paper refinement): the PUD driver
    knows both cost models and only offloads when DRAM execution is cheaper —
    sub-row ops stay on the CPU, so PUMA never *loses* to the baseline.

    The in-DRAM part executes channel-parallel: PUD rows are partitioned by
    owning channel and the burst costs ``max`` over per-channel row counts
    (at ``channels=1`` this is exactly the old serial sum).  Passing a
    ``controller`` additionally queues the burst on the per-channel
    ``busy_until`` frontiers — contention with earlier ops and SB<->PIM mode
    switches then show up in ``t_ns``, and the dispatch advances the
    controller state (unless the adaptive driver picks the CPU, in which
    case the queues are left untouched).

    With an ``injector``, rows in blacklisted subarrays are planned onto the
    CPU up front, and the surviving PUD rows may fault mid-flight at the
    injected RowClone error rate: a faulted row's AAP time is wasted and the
    row is re-executed on the CPU — the graceful-degradation pricing the
    chaos benchmark measures.

    With a ``recorder`` (:class:`repro.trace.record.TraceRecorder` — duck-
    typed, only ``emit`` is used), the fully priced op lands in the trace as
    one ``pud_op`` event, emitted *before* the controller dispatch so the
    replay executor can re-run the queue-state-aware peek against
    un-advanced controller state.  ``label`` is free-form provenance (the
    offload model passes ``arch/allocator/weight-name``).
    """
    plan = plan_rows(op, operands, amap, injector=injector)
    region = amap.region_bytes
    size = min(a.size for a in operands)

    pud_rows = sum(plan.in_pud)
    # CPU-path bytes: full regions for interior misses; the final partial
    # row contributes only its real tail bytes.
    cpu_rows = plan.n_rows - pud_rows
    cpu_bytes = cpu_rows * region
    if plan.tail_bytes:  # last row is a CPU partial row, not a full region
        cpu_bytes += plan.tail_bytes - region

    rows_per_channel: Optional[List[int]] = None
    row_ns = model.pud_row_ns(op)
    if pud_rows:
        if controller is not None:
            est = controller.peek_pud(plan.pud_subarrays(), row_ns)
            t = est.latency_ns
            rows_per_channel = est.rows_per_channel
        else:
            counts = plan.channel_rows(amap)
            t = int(counts.max()) * row_ns
            rows_per_channel = counts.tolist()
    else:
        t = 0.0
    if cpu_rows:
        t += model.cpu_op_overhead_ns
        t += model.cpu_ns(op, cpu_bytes, cpu_rows)
    elif pud_rows:
        t += model.cpu_op_overhead_ns  # syscall into the PUD driver

    t_cpu = model.cpu_op_overhead_ns + model.cpu_ns(op, size, max(plan.n_rows, 1))
    n_faulted = 0
    if adaptive and t > t_cpu:
        t = t_cpu
        rows_per_channel = None  # driver picked the CPU: nothing dispatched
    elif pud_rows and injector is not None:
        # mid-flight RowClone faults: the AAP time above is already
        # spent; each faulted row is gracefully retried on the CPU.
        faults = injector.rowclone_faults(plan.pud_subarrays().tolist())
        n_faulted = int(faults.sum())
        if n_faulted:
            plan.faulted_rows = n_faulted
            if not cpu_rows:  # first CPU entry for this op: pay setup
                t += model.cpu_op_overhead_ns
            t += model.cpu_ns(op, n_faulted * region, n_faulted)
    if recorder is not None:
        # emitted before the dispatch below: replay peeks the controller
        # queues in recorded state, then applies the ctrl_pud event.
        recorder.emit(
            "pud_op",
            op=op, label=label, size=int(size), n_rows=int(plan.n_rows),
            pud_rows=int(pud_rows), cpu_rows=int(cpu_rows),
            cpu_bytes=int(cpu_bytes), tail_bytes=int(plan.tail_bytes),
            region_bytes=int(region),
            rows_per_channel=(
                None if rows_per_channel is None
                else [int(n) for n in rows_per_channel]
            ),
            ctrl=controller is not None,
            adaptive=bool(adaptive),
            faulted_rows=int(n_faulted),
            t_ns=float(t), t_cpu_ns=float(t_cpu),
        )
    if controller is not None and rows_per_channel is not None and pud_rows:
        controller.dispatch_pud(plan.pud_subarrays(), row_ns)
    return SimResult(
        op, size, plan.pud_fraction, t, t_cpu, rows_per_channel, n_faulted
    )


# ---------------------------------------------------------------------------
# Migration pricing: what one compaction pass costs (ISSUE 8).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MigrationCost:
    """Price of one compaction pass's data movement."""

    rowclone_rows: int          # same-subarray moves executed as RowClone
    cpu_rows: int               # cross-subarray moves the substrate can't do
    bytes_moved: int
    rowclone_ns: float          # in-DRAM burst latency (channel-parallel)
    cpu_copy_ns: float          # host-side streaming copy time
    @property
    def total_ns(self) -> float:
        return self.rowclone_ns + self.cpu_copy_ns


def price_migration(
    rowclone_subarrays: Sequence[int],
    cpu_rows: int,
    row_bytes: int,
    *,
    channels: int = 1,
    model: PudCostModel = PudCostModel(),
    controller: Optional[DramController] = None,
    cpu_pas: Optional[np.ndarray] = None,
) -> MigrationCost:
    """Price one compaction pass (see :mod:`repro.robustness.compaction`).

    Same-subarray moves are RowClone FPM row copies — ``pud_row_ns("copy")``
    per row, executed channel-parallel (``rowclone_subarrays`` carries one
    global subarray/arena ID per such move; the owning channel is
    ``id % channels``).  Cross-subarray moves fall back to a host streaming
    copy priced by :meth:`PudCostModel.cpu_ns`, one op-call overhead per
    pass.  With a ``controller``, both kinds are *dispatched* — the RowClone
    rows as PUD bursts, the CPU copies' cacheline traffic (``cpu_pas``) as
    normal accesses — so the pass occupies the channel frontiers and
    competes with live traffic; the maintenance pass itself is serial
    (RowClone burst, then the host copy), matching a stop-the-row background
    defragmenter.
    """
    row_ns = model.pud_row_ns("copy")
    sas = np.asarray(rowclone_subarrays, dtype=np.int64)
    if controller is not None:
        start = controller.now_ns
        done = controller.dispatch_migration(sas, row_ns, cpu_pas)
        rowclone_ns = done - start
    elif sas.size:
        counts = np.bincount(sas % channels, minlength=channels)
        rowclone_ns = float(int(counts.max()) * row_ns)
    else:
        rowclone_ns = 0.0
    cpu_copy_ns = 0.0
    if cpu_rows:
        cpu_copy_ns = model.cpu_op_overhead_ns + model.cpu_ns(
            "copy", cpu_rows * row_bytes, cpu_rows
        )
    return MigrationCost(
        rowclone_rows=int(sas.size),
        cpu_rows=int(cpu_rows),
        bytes_moved=(int(sas.size) + int(cpu_rows)) * row_bytes,
        rowclone_ns=rowclone_ns,
        cpu_copy_ns=cpu_copy_ns,
    )


# ---------------------------------------------------------------------------
# Functional execution: actually perform the op through the page tables on a
# numpy "physical memory", so tests can assert that PUD dispatch computes the
# same bytes as a plain vector op regardless of which rows took which path.
# ---------------------------------------------------------------------------

def _apply_rowwise(op: OpKind, dst: np.ndarray, srcs: List[np.ndarray]) -> None:
    if op == "zero":
        dst[:] = 0
    elif op == "copy":
        dst[:] = srcs[0]
    elif op == "and":
        np.bitwise_and(srcs[0], srcs[1], out=dst)
    elif op == "or":
        np.bitwise_or(srcs[0], srcs[1], out=dst)
    elif op == "not":
        np.bitwise_not(srcs[0], out=dst)
    else:
        raise ValueError(op)


def execute_op(
    op: OpKind,
    operands: Sequence[Allocation],
    phys: np.ndarray,
    amap: AddressMap,
    controller: Optional[DramController] = None,
    model: Optional[PudCostModel] = None,
    injector: Optional["FaultInjector"] = None,
) -> RowPlan:
    """Execute ``op`` with dst = operands[-1], srcs = operands[:-1].

    Every byte moves through the VA->PA mapping; PUD-eligible rows use the
    row-granular path (modelling in-DRAM execution), the rest byte-copies via
    the "CPU".  Both paths write the same bytes — the point is to validate
    that the *dispatch plan* is sound, which tests assert by comparing
    against a whole-buffer numpy op.

    Dispatch order mirrors the hardware's per-channel command streams: PUD
    rows are partitioned by owning channel and each channel's rows issue as
    one burst (rows are disjoint regions, so the bytes are identical to the
    row-index order the single-channel model used).  CPU rows follow.  With
    a ``controller``, the same partition is queued on the per-channel
    frontiers so execution traffic shows up in the occupancy report.

    With an ``injector``, blacklisted subarrays never enter DRAM dispatch
    and PUD rows may fault mid-flight (RowClone copy failure): a faulted
    row is transparently re-executed on the CPU path — same bytes, graceful
    degradation — and counted in the returned plan's ``faulted_rows``.
    """
    plan = plan_rows(op, operands, amap, injector=injector)
    region = amap.region_bytes
    size = min(a.size for a in operands)
    dst, srcs = operands[-1], list(operands[:-1])

    def read(a: Allocation, off: int, n: int) -> np.ndarray:
        out = np.empty(n, np.uint8)
        done = 0
        for pa, run in a.runs(off, n):
            out[done : done + run] = phys[pa : pa + run]
            done += run
        return out

    def write(a: Allocation, off: int, buf: np.ndarray) -> None:
        done = 0
        for pa, run in a.runs(off, len(buf)):
            phys[pa : pa + run] = buf[done : done + run]
            done += run

    def do_row(r: int) -> None:
        off = r * region
        # PUD rows operate on the full (owned, padded) region; the final CPU
        # row only touches the real tail bytes.
        n = region
        if not plan.in_pud[r] and r == plan.n_rows - 1 and plan.tail_bytes:
            n = plan.tail_bytes
        src_rows = [read(s, off, n) for s in srcs]
        out = np.empty(n, np.uint8)
        _apply_rowwise(op, out, src_rows)
        write(dst, off, out)

    if plan.n_rows:
        rows = np.arange(plan.n_rows)
        planned = np.asarray(plan.in_pud, dtype=bool)
        in_pud = planned
        if injector is not None and planned.any():
            # mid-flight RowClone faults: the row leaves the DRAM burst and
            # re-executes on the CPU (identical bytes — graceful degradation)
            faults = injector.rowclone_faults(
                plan.subarrays[planned].tolist()
            )
            if faults.any():
                idx = rows[planned][faults]
                in_pud = planned.copy()
                in_pud[idx] = False
                plan.faulted_rows = int(faults.sum())
        chans = np.where(
            in_pud, amap.channel_of_subarray(plan.subarrays), -1
        )
        # one burst per channel, in channel order; CPU rows (chan == -1) last
        for c in range(amap.geo.channels):
            for r in rows[chans == c].tolist():
                do_row(r)
        for r in rows[chans == -1].tolist():
            do_row(r)
        if controller is not None and planned.any():
            # faulted rows still spent their AAP time in DRAM: charge the
            # whole planned burst, not just the rows that completed there.
            controller.dispatch_pud(
                plan.pud_subarrays(), (model or PudCostModel()).pud_row_ns(op)
            )
    return plan
