"""PUD executability + timing model (RowClone / Ambit substrate, paper §3).

The evaluated substrate executes, *in DRAM*:

* ``zero``  — RowClone zero-init  (copy from a reserved all-zeros row),
* ``copy``  — RowClone FPM intra-subarray row copy,
* ``and/or/not`` — Ambit triple-row-activation Boolean ops,

and each operation proceeds row by row.  A row-granular op is PUD-executable
iff **every operand's row** (i) is physically contiguous, (ii) starts at a
rank-row boundary, and (iii) all operand rows share one global subarray —
exactly the paper's criterion ("source and destination operands are
contiguous in physical memory and DRAM-row-aligned", same subarray).
Rows that fail fall back to the CPU, as does the sub-row tail of every
allocation.

Timing constants approximate DDR3/4 values used by RowClone [104] and
Ambit [101]: an AAP (ACTIVATE-ACTIVATE-PRECHARGE) command sequence costs
~tRAS+tRP ≈ 90 ns and touches a full 8 KB rank-row.  The CPU fallback prices
a streaming read/write through the memory hierarchy.  Absolute numbers only
set the scale; the paper's Figure 2 normalizes to the malloc baseline, and
so do we.

Planning fast path
------------------

``plan_rows`` no longer probes each logical row with scalar
``contiguous_run``/``region_subarray`` calls.  Each operand's per-row global
subarray (or -1 where the row is not PUD-capable) is computed as one numpy
array — a ``searchsorted`` over the allocation's coalesced extents plus the
batch decode from :mod:`repro.core.dram` — and memoized on the
``Allocation`` (the mapping is immutable after construction, so the cache
lives as long as the allocation; freeing drops the allocation and the table
with it).  Executability is then a vectorized equality across operand
tables.  ``execute_op`` walks :meth:`Allocation.runs` so every physically
contiguous run moves as one slice instead of byte-by-byte ``pa_of`` probing.
Property tests pin both fast paths to the original scalar semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.allocators import Allocation
from repro.core.dram import AddressMap

__all__ = [
    "OpKind",
    "PudCostModel",
    "RowPlan",
    "row_subarray_table",
    "plan_rows",
    "simulate_op",
    "execute_op",
]


OpKind = str  # "zero" | "copy" | "and" | "or" | "not"

#: operands (incl. destination) per op
N_OPERANDS: Dict[str, int] = {"zero": 1, "copy": 2, "and": 3, "or": 3, "not": 2}

#: AAP sequences per row for each PUD op (RowClone/Ambit command counts)
PUD_AAPS: Dict[str, int] = {"zero": 1, "copy": 2, "and": 4, "or": 4, "not": 3}


@dataclasses.dataclass(frozen=True)
class PudCostModel:
    aap_ns: float = 90.0            # ACT(tRAS 35ns) + ACT + PRE(tRP 15ns) ≈ 90ns
    pud_issue_ns: float = 20.0      # memory-controller command overhead / row
    cpu_bw_gbs: float = 10.0        # streaming CPU bandwidth (read xor write)
    cpu_op_overhead_ns: float = 250.0   # call + loop setup per operation
    cpu_row_touch_ns: float = 40.0      # per-row TLB/prefetch restart on the
                                        # fallback path (data pulled to CPU)

    def pud_row_ns(self, op: OpKind) -> float:
        return PUD_AAPS[op] * self.aap_ns + self.pud_issue_ns

    def cpu_bytes_moved(self, op: OpKind, nbytes: int) -> int:
        # zero: write N; copy: read N + write N; and/or: 2 reads + 1 write;
        # not: read + write.
        streams = {"zero": 1, "copy": 2, "and": 3, "or": 3, "not": 2}[op]
        return streams * nbytes

    def cpu_ns(self, op: OpKind, nbytes: int, nrows: int = 1) -> float:
        # Unit identity, made explicit: 1 GB/s = 1e9 B / 1e9 ns = exactly
        # 1 byte/ns, so a bandwidth of ``cpu_bw_gbs`` GB/s moves
        # ``cpu_bw_gbs`` bytes per nanosecond.  (Not a coincidence of the
        # default value — the 1e9s cancel for any parameter setting.)
        bytes_per_ns = self.cpu_bw_gbs
        move = self.cpu_bytes_moved(op, nbytes) / bytes_per_ns
        return move + nrows * self.cpu_row_touch_ns


@dataclasses.dataclass
class RowPlan:
    """Per-row execution decision for one op over parallel operands."""

    n_rows: int                 # full rows in the logical buffers
    in_pud: List[bool]          # len n_rows
    tail_bytes: int             # sub-row remainder (always CPU)

    @property
    def pud_fraction(self) -> float:
        if self.n_rows == 0:
            return 0.0
        return sum(self.in_pud) / self.n_rows


def _row_subarray(
    alloc: Allocation, row: int, region_bytes: int, amap: AddressMap
) -> Optional[int]:
    """Global subarray of logical row ``row``; None if not PUD-capable.

    Scalar reference path — ``plan_rows`` uses the vectorized
    :func:`row_subarray_table`; property tests assert they agree.
    """
    off = row * region_bytes
    pa = alloc.contiguous_run(off, region_bytes)
    if pa is None or not amap.region_is_aligned(pa):
        return None
    return amap.region_subarray(pa)


def row_subarray_table(alloc: Allocation, amap: AddressMap) -> np.ndarray:
    """Per-row global subarray of ``alloc`` as an int64 array (-1 = not
    PUD-capable), memoized on the allocation.

    Row ``r`` is PUD-capable iff the full region ``[r*region, (r+1)*region)``
    sits inside one coalesced extent (ownership + physical contiguity) at a
    region-aligned physical base; its value is then the region's global
    subarray from the batch decode.
    """
    cached = alloc._row_sa_cache.get(id(amap))
    if cached is not None and cached[0] is amap:
        return cached[1]
    region = amap.region_bytes
    n_rows = -(-alloc.size // region)
    offs = np.arange(n_rows, dtype=np.int64) * region
    va_offs = np.asarray(alloc._va_offs, dtype=np.int64)
    ends = np.asarray(alloc._va_ends, dtype=np.int64)
    pas = np.asarray(alloc._pas, dtype=np.int64)
    idx = np.searchsorted(va_offs, offs, side="right") - 1
    idxc = np.clip(idx, 0, len(va_offs) - 1)
    pa = pas[idxc] + offs - va_offs[idxc]
    ok = (idx >= 0) & (offs + region <= ends[idxc]) & (pa % region == 0)
    table = np.where(ok, amap.region_subarrays(pa), -1)
    alloc._row_sa_cache[id(amap)] = (amap, table)
    return table


def plan_rows(
    op: OpKind, operands: Sequence[Allocation], amap: AddressMap
) -> RowPlan:
    """Decide, row by row, whether the op can execute in DRAM.

    PUD ops act on whole rows, so the final *partial* logical row can still
    execute in DRAM when every allocator padded the allocation out to a full
    owned region (PUMA and per-mmap huge pages do; heap allocators do not —
    their extents stop at the requested size, and operating on the full row
    would clobber a neighbour).  The row table's full-region contiguity
    check is exactly that ownership test.
    """
    assert len(operands) == N_OPERANDS[op], (op, len(operands))
    size = min(a.size for a in operands)
    region = amap.region_bytes
    n_full, tail = divmod(size, region)
    n_rows = n_full + (1 if tail else 0)
    if n_rows == 0:
        return RowPlan(n_rows=0, in_pud=[], tail_bytes=0)
    tables = [row_subarray_table(a, amap)[:n_rows] for a in operands]
    ok = tables[0] != -1
    for t in tables[1:]:
        ok = ok & (t == tables[0])
    in_pud = ok.tolist()
    tail_bytes = 0 if (not tail or in_pud[-1]) else tail
    return RowPlan(n_rows=n_rows, in_pud=in_pud, tail_bytes=tail_bytes)


@dataclasses.dataclass
class SimResult:
    op: OpKind
    size: int
    pud_fraction: float
    t_ns: float          # time with the PUD substrate available
    t_cpu_ns: float      # time if everything ran on the CPU

    @property
    def speedup_vs_cpu(self) -> float:
        return self.t_cpu_ns / self.t_ns if self.t_ns > 0 else float("inf")


def simulate_op(
    op: OpKind,
    operands: Sequence[Allocation],
    amap: AddressMap,
    model: PudCostModel = PudCostModel(),
    adaptive: bool = True,
) -> SimResult:
    """Price one op.  ``adaptive`` (beyond-paper refinement): the PUD driver
    knows both cost models and only offloads when DRAM execution is cheaper —
    sub-row ops stay on the CPU, so PUMA never *loses* to the baseline."""
    plan = plan_rows(op, operands, amap)
    region = amap.region_bytes
    size = min(a.size for a in operands)

    pud_rows = sum(plan.in_pud)
    # CPU-path bytes: full regions for interior misses; the final partial
    # row contributes only its real tail bytes.
    cpu_rows = plan.n_rows - pud_rows
    cpu_bytes = cpu_rows * region
    if plan.tail_bytes:  # last row is a CPU partial row, not a full region
        cpu_bytes += plan.tail_bytes - region
    t = pud_rows * model.pud_row_ns(op)
    if cpu_rows:
        t += model.cpu_op_overhead_ns
        t += model.cpu_ns(op, cpu_bytes, cpu_rows)
    elif pud_rows:
        t += model.cpu_op_overhead_ns  # syscall into the PUD driver

    t_cpu = model.cpu_op_overhead_ns + model.cpu_ns(op, size, max(plan.n_rows, 1))
    if adaptive and t > t_cpu:
        t = t_cpu
    return SimResult(op, size, plan.pud_fraction, t, t_cpu)


# ---------------------------------------------------------------------------
# Functional execution: actually perform the op through the page tables on a
# numpy "physical memory", so tests can assert that PUD dispatch computes the
# same bytes as a plain vector op regardless of which rows took which path.
# ---------------------------------------------------------------------------

def _apply_rowwise(op: OpKind, dst: np.ndarray, srcs: List[np.ndarray]) -> None:
    if op == "zero":
        dst[:] = 0
    elif op == "copy":
        dst[:] = srcs[0]
    elif op == "and":
        np.bitwise_and(srcs[0], srcs[1], out=dst)
    elif op == "or":
        np.bitwise_or(srcs[0], srcs[1], out=dst)
    elif op == "not":
        np.bitwise_not(srcs[0], out=dst)
    else:
        raise ValueError(op)


def execute_op(
    op: OpKind,
    operands: Sequence[Allocation],
    phys: np.ndarray,
    amap: AddressMap,
) -> RowPlan:
    """Execute ``op`` with dst = operands[-1], srcs = operands[:-1].

    Every byte moves through the VA->PA mapping; PUD-eligible rows use the
    row-granular path (modelling in-DRAM execution), the rest byte-copies via
    the "CPU".  Both paths write the same bytes — the point is to validate
    that the *dispatch plan* is sound, which tests assert by comparing
    against a whole-buffer numpy op.
    """
    plan = plan_rows(op, operands, amap)
    region = amap.region_bytes
    size = min(a.size for a in operands)
    dst, srcs = operands[-1], list(operands[:-1])

    def read(a: Allocation, off: int, n: int) -> np.ndarray:
        out = np.empty(n, np.uint8)
        done = 0
        for pa, run in a.runs(off, n):
            out[done : done + run] = phys[pa : pa + run]
            done += run
        return out

    def write(a: Allocation, off: int, buf: np.ndarray) -> None:
        done = 0
        for pa, run in a.runs(off, len(buf)):
            phys[pa : pa + run] = buf[done : done + run]
            done += run

    for r in range(plan.n_rows):
        off = r * region
        # PUD rows operate on the full (owned, padded) region; the final CPU
        # row only touches the real tail bytes.
        n = region
        if not plan.in_pud[r] and r == plan.n_rows - 1 and plan.tail_bytes:
            n = plan.tail_bytes
        src_rows = [read(s, off, n) for s in srcs]
        out = np.empty(n, np.uint8)
        _apply_rowwise(op, out, src_rows)
        write(dst, off, out)
    return plan
