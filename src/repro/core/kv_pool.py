"""Paged KV-cache pool with PUMA placement — the serving-side integration.

One pool holds the KV blocks of *all* live requests for *all* layers:

  K pool: (num_blocks, block_size, kv_heads, head_dim)   per layer-group
  V pool: same

A request's logical KV stream is a :class:`~repro.core.arena.TileHandle`
(one tile = one block).  Placement uses PUMA policy: the first request block
goes worst-fit, subsequent blocks of the same request go ``extend`` (same
arena, adjacent slot when possible), and the V handle is ``alloc_align``-ed
against the K handle so K/V block *k* live at mirrored offsets.

The device side keeps everything as jnp arrays plus an int32 *block table*
(max_seqs, max_blocks) — the TPU-idiomatic replacement for the paper's
re-mmap (see DESIGN.md §2).  `paged_attention` consumes the table; its fast
path coalesces contiguous block runs into single DMA streams, so PUMA
placement translates directly into fewer descriptors.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arena import TileHandle, TilePool

if TYPE_CHECKING:
    from repro.robustness.faults import FaultInjector
    from repro.robustness.journal import Journal

__all__ = ["KVPoolConfig", "PagedKVPool"]


@dataclasses.dataclass(frozen=True)
class KVPoolConfig:
    num_blocks: int = 1024
    block_size: int = 16            # tokens per block
    kv_heads: int = 8
    head_dim: int = 128
    n_layers: int = 1               # layers sharing this pool object
    max_seqs: int = 64
    max_blocks_per_seq: int = 256
    blocks_per_arena: int = 64      # "subarray" capacity
    n_channels: int = 1             # memory channels the arenas stripe over
    policy: str = "puma"
    dtype: str = "bfloat16"

    @property
    def n_arenas(self) -> int:
        assert self.num_blocks % self.blocks_per_arena == 0
        return self.num_blocks // self.blocks_per_arena

    def __post_init__(self):
        n_arenas = self.num_blocks // self.blocks_per_arena
        if self.n_channels < 1 or n_arenas % self.n_channels:
            raise ValueError(
                f"n_channels={self.n_channels} must divide "
                f"n_arenas={n_arenas} (num_blocks/blocks_per_arena)"
            )


class PagedKVPool:
    """Host bookkeeping + device buffers for paged KV serving."""

    def __init__(
        self,
        cfg: KVPoolConfig,
        injector: Optional["FaultInjector"] = None,
        journal: Optional["Journal"] = None,
    ):
        self.cfg = cfg
        #: crash-consistency journal, shared with the inner tile pool so
        #: slot-level (kv_*) and tile-level events form one total order.
        self.journal = journal
        self.pool = TilePool(
            cfg.n_arenas, cfg.blocks_per_arena, cfg.policy,
            n_channels=cfg.n_channels, injector=injector, journal=journal,
        )
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, cfg.num_blocks, cfg.block_size, cfg.kv_heads, cfg.head_dim)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        # seq slot -> (k_handle, token_count)
        self._seqs: Dict[int, Tuple[TileHandle, int]] = {}
        self._free_slots = list(range(cfg.max_seqs))
        #: trace recorder (:class:`repro.trace.record.TraceRecorder`);
        #: the serving engine wires it in — None = no tracing overhead.
        self.trace = None

    # -- capacity reasoning (admission control) -------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        """KV blocks needed to hold ``n_tokens`` tokens."""
        return -(-n_tokens // self.cfg.block_size)

    @property
    def capacity_blocks(self) -> int:
        """Hard per-sequence block ceiling: a request needing more than this
        can *never* be admitted, regardless of pool state."""
        return min(self.cfg.num_blocks, self.cfg.max_blocks_per_seq)

    # -- request lifecycle ----------------------------------------------------
    def admit(self, n_prompt_tokens: int) -> Optional[int]:
        """Admit a request; allocate blocks for its prompt. Returns seq slot."""
        if not self._free_slots:
            return None
        blocks = -(-n_prompt_tokens // self.cfg.block_size)
        h = self.pool.alloc(blocks)
        if h is None:
            return None
        slot = self._free_slots.pop(0)
        self._seqs[slot] = (h, n_prompt_tokens)
        if self.journal is not None:
            self.journal.append(
                "kv_admit", slot=slot, hid=h.hid, ntok=n_prompt_tokens
            )
        if self.trace is not None:
            self.trace.on_admit(slot, h.tiles, alloc=self.cfg.policy)
        return slot

    def fork(
        self, slot: int, copy_data: bool = True, use_kernel: bool = False
    ) -> Optional[int]:
        """Beam/prefix fork: new sequence whose blocks are PUMA-aligned to
        the parent's, with the KV pages cloned in-pool — the RowClone
        analogue (``pud_bulk.pool_block_copy``; PUMA placement keeps source
        and destination in the same arena, so on the PUD substrate the copy
        is a same-subarray row-to-row transfer)."""
        if slot not in self._seqs or not self._free_slots:
            return None
        parent, ntok = self._seqs[slot]
        h = self.pool.alloc_align(len(parent.tiles), parent)
        if h is None:
            return None
        if copy_data and parent.tiles:
            from repro.kernels.pud_bulk.ops import pool_block_copy

            src = jnp.asarray(parent.tiles, jnp.int32)
            dst = jnp.asarray(h.tiles, jnp.int32)
            L = self.cfg.n_layers
            nb = self.cfg.num_blocks
            # fold the layer dim into the block index so one kernel call
            # clones every layer's pages
            offs = (jnp.arange(L, dtype=jnp.int32) * nb)[:, None]
            src_all = (src[None, :] + offs).reshape(-1)
            dst_all = (dst[None, :] + offs).reshape(-1)
            kflat = self.k.reshape((L * nb,) + self.k.shape[2:])
            vflat = self.v.reshape((L * nb,) + self.v.shape[2:])
            self.k = pool_block_copy(kflat, src_all, dst_all, use_kernel=use_kernel).reshape(self.k.shape)
            self.v = pool_block_copy(vflat, src_all, dst_all, use_kernel=use_kernel).reshape(self.v.shape)
        new_slot = self._free_slots.pop(0)
        self._seqs[new_slot] = (h, ntok)
        if self.journal is not None:
            self.journal.append("kv_fork", slot=new_slot, hid=h.hid, ntok=ntok)
        return new_slot

    def append_token(self, slot: int) -> bool:
        """Decode step bookkeeping: extend by a block when the current one fills."""
        h, ntok = self._seqs[slot]
        ntok += 1
        if ntok > len(h.tiles) * self.cfg.block_size:
            if not self.pool.extend(h, 1):
                return False
            if self.trace is not None:
                contig = len(h.tiles) < 2 or h.tiles[-1] == h.tiles[-2] + 1
                self.trace.on_extend(slot, h.tiles[-1], contig)
        self._seqs[slot] = (h, ntok)
        if self.journal is not None:
            self.journal.append("kv_append", slot=slot)
        return True

    def release(self, slot: int) -> None:
        h, _ = self._seqs.pop(slot)
        self.pool.free(h)
        if self.journal is not None:
            self.journal.append("kv_release", slot=slot)
        if self.trace is not None:
            self.trace.on_release(slot)
        self._free_slots.append(slot)

    # -- maintenance ----------------------------------------------------------
    def compact(
        self,
        max_moves: int = 128,
        use_kernel: bool = False,
        model=None,
        controller=None,
    ):
        """One defragmentation pass over the block pool.

        Plans with :func:`~repro.robustness.compaction.plan_pool_compaction`
        (intra-arena run repair first — RowClone-cheap — then arena
        evacuation), applies every planned move to the device K/V buffers
        with one batched ``pool_block_copy`` per pool (the plan guarantees
        sources and destinations are disjoint), then commits the
        bookkeeping through :func:`~repro.robustness.compaction.compact_pool`
        — which journals the pass and prices it.  Live block tables pick up
        the new placement automatically because the moves mutate the
        handles' tile lists in place.

        Returns the :class:`~repro.robustness.compaction.CompactionReport`,
        or ``None`` when the planner found nothing worth moving.
        """
        from repro.robustness.compaction import compact_pool, plan_pool_compaction

        plan = plan_pool_compaction(self.pool, max_moves=max_moves)
        if not plan.moves:
            return None
        from repro.kernels.pud_bulk.ops import pool_block_copy

        src = jnp.asarray([m.src for m in plan.moves], jnp.int32)
        dst = jnp.asarray([m.dst for m in plan.moves], jnp.int32)
        L = self.cfg.n_layers
        nb = self.cfg.num_blocks
        # fold the layer dim into the block index so one kernel call moves
        # every layer's pages (same trick as fork)
        offs = (jnp.arange(L, dtype=jnp.int32) * nb)[:, None]
        src_all = (src[None, :] + offs).reshape(-1)
        dst_all = (dst[None, :] + offs).reshape(-1)
        kflat = self.k.reshape((L * nb,) + self.k.shape[2:])
        vflat = self.v.reshape((L * nb,) + self.v.shape[2:])
        self.k = pool_block_copy(
            kflat, src_all, dst_all, use_kernel=use_kernel
        ).reshape(self.k.shape)
        self.v = pool_block_copy(
            vflat, src_all, dst_all, use_kernel=use_kernel
        ).reshape(self.v.shape)
        cfg = self.cfg
        tile_bytes = (
            2 * cfg.n_layers * cfg.block_size * cfg.kv_heads * cfg.head_dim
            * jnp.dtype(cfg.dtype).itemsize
        )
        report = compact_pool(
            self.pool, plan,
            tile_bytes=tile_bytes, model=model, controller=controller,
        )
        if self.trace is not None and report is not None:
            self.trace.on_compact(
                [(m.src, m.dst) for m in plan.moves], report
            )
        return report

    # -- trace helpers -----------------------------------------------------------
    def tiles_of(self, slot: int) -> List[int]:
        """Current tile list of a live sequence (trace emission)."""
        return list(self._seqs[slot][0].tiles)

    def block_of_token(self, slot: int) -> int:
        """Pool block holding the sequence's latest token — the block a
        decode-step ``write_token_kv`` just landed in."""
        h, ntok = self._seqs[slot]
        return h.tiles[(ntok - 1) // self.cfg.block_size]

    # -- device views -----------------------------------------------------------
    def block_table(self) -> np.ndarray:
        """(max_seqs, max_blocks) int32, -1 padded."""
        cfg = self.cfg
        tbl = np.full((cfg.max_seqs, cfg.max_blocks_per_seq), -1, np.int32)
        for slot, (h, _) in self._seqs.items():
            n = min(len(h.tiles), cfg.max_blocks_per_seq)
            tbl[slot, :n] = h.tiles[:n]
        return tbl

    def seq_lens(self) -> np.ndarray:
        out = np.zeros((self.cfg.max_seqs,), np.int32)
        for slot, (_, ntok) in self._seqs.items():
            out[slot] = ntok
        return out

    def write_prompt_kv(
        self, slot: int, layer: int, k: jax.Array, v: jax.Array
    ) -> None:
        """Scatter a prompt's K/V (n_tokens, kv_heads, head_dim) into the pool."""
        cfg = self.cfg
        h, _ = self._seqs[slot]
        n = k.shape[0]
        pad = len(h.tiles) * cfg.block_size - n
        if pad:
            k = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
        kb = k.reshape(len(h.tiles), cfg.block_size, cfg.kv_heads, cfg.head_dim)
        vb = v.reshape(len(h.tiles), cfg.block_size, cfg.kv_heads, cfg.head_dim)
        idx = jnp.asarray(h.tiles, jnp.int32)
        self.k = self.k.at[layer, idx].set(kb.astype(self.k.dtype))
        self.v = self.v.at[layer, idx].set(vb.astype(self.v.dtype))

    def write_token_kv(
        self, slot: int, layer: int, k1: jax.Array, v1: jax.Array
    ) -> None:
        """Write one decoded token's K/V (kv_heads, head_dim)."""
        cfg = self.cfg
        h, ntok = self._seqs[slot]
        pos = ntok - 1
        block = h.tiles[pos // cfg.block_size]
        off = pos % cfg.block_size
        self.k = self.k.at[layer, block, off].set(k1.astype(self.k.dtype))
        self.v = self.v.at[layer, block, off].set(v1.astype(self.v.dtype))

    def occupancy(self) -> Dict[str, float]:
        """Point-in-time pool occupancy sample (all floats, JSON-friendly):
        tile counts, used fraction, and sequence-slot pressure.  The serving
        load harness samples this every engine step via ``step_hooks``."""
        total = self.pool.total_tiles
        free = self.pool.free_tiles()
        return {
            "total_tiles": float(total),
            "free_tiles": float(free),
            "used_tiles": float(total - free),
            "used_fraction": (total - free) / total if total else 0.0,
            "live_seqs": float(len(self._seqs)),
            "free_slots": float(len(self._free_slots)),
        }

    # -- PUMA metric --------------------------------------------------------------
    def contiguity_report(self) -> Dict[str, float]:
        """Pool-wide contiguous-run statistics (the paper's '% in PUD'
        analogue) plus the channel figure of merit: ``channel_balance`` is
        mean/max used blocks per channel (1.0 = block tables perfectly
        striped across the channel-parallel substrate)."""
        fracs, runs, tiles = [], 0, 0
        for h, _ in self._seqs.values():
            fracs.append(h.contiguous_run_fraction())
            runs += len(h.runs())
            tiles += len(h.tiles)
        occ = self.pool.channel_occupancy()
        return {
            "mean_contiguous_fraction": float(np.mean(fracs)) if fracs else 1.0,
            "descriptors_per_tile": runs / tiles if tiles else 0.0,
            "live_seqs": float(len(self._seqs)),
            "channels": float(occ["channels"]),
            "channel_balance": float(occ["balance"]),
        }

    def channel_occupancy(self) -> Dict[str, object]:
        """Per-channel used/free block counts (detail behind the balance)."""
        return self.pool.channel_occupancy()
