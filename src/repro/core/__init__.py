"""PUMA core: the paper's contribution (allocation policy + PUD model) and
its TPU adaptation (arena pool + paged KV cache)."""
from repro.core.dram import (
    AddressMap,
    DramGeometry,
    InterleaveScheme,
    BANK_REGION_SCHEME,
    CACHELINE_INTERLEAVED_SCHEME,
    default_map,
)
from repro.core.allocators import (
    Allocation,
    HugePageModel,
    MallocModel,
    PhysicalMemory,
    PosixMemalignModel,
)
from repro.core.puma import PumaAllocator
from repro.core.pud import PudCostModel, execute_op, plan_rows, simulate_op
from repro.core.arena import TileHandle, TilePool
from repro.core.kv_pool import KVPoolConfig, PagedKVPool

__all__ = [
    "AddressMap",
    "DramGeometry",
    "InterleaveScheme",
    "BANK_REGION_SCHEME",
    "CACHELINE_INTERLEAVED_SCHEME",
    "default_map",
    "Allocation",
    "HugePageModel",
    "MallocModel",
    "PhysicalMemory",
    "PosixMemalignModel",
    "PumaAllocator",
    "PudCostModel",
    "execute_op",
    "plan_rows",
    "simulate_op",
    "TileHandle",
    "TilePool",
    "KVPoolConfig",
    "PagedKVPool",
]
