"""TPU adaptation of PUMA: a tile-granular, arena-indexed device memory pool.

The HBM of a TPU chip plays the role of the DRAM channel; we pre-allocate one
flat device buffer (the ``pim_preallocate`` analogue) and manage it host-side
as ``n_arenas`` arenas ("subarrays") of ``tiles_per_arena`` tiles ("rows").
A tile is the hardware-aligned unit — for KV-cache blocks a tile is one
(block_size, kv_heads, head_dim) page whose last two dims are (8,128)-lane
aligned; for bitplane buffers it is an (8,128) uint32 tile.

Placement policy is PUMA's, verbatim:

* ``alloc``       — worst-fit over arenas (ordered free-count array),
                    draining the emptiest arena in *contiguous slot runs*;
* ``alloc_align`` — walk a hint handle's tiles and co-locate tile *k* in the
                    same arena (adjacent slot when free), worst-fit fallback;
* handles live in a hashmap so later aligned allocations can find the hint.

Why it matters on TPU: kernels that stream a handle's tiles (paged attention,
bulk copy/zero) issue one DMA descriptor per *contiguous run* of tile
indices.  PUMA placement maximizes run length exactly the way it maximizes
same-subarray residency in DRAM; the metric ``contiguous_run_fraction`` is
the TPU analogue of the paper's "% of operations executed in PUD".

Baseline policies (``first_fit``, ``random``) mirror malloc/hugepage for the
benchmark comparison.

Channel striping (``n_channels > 1``): arenas are assigned round-robin to
channels (``arena % n_channels`` — mirroring the DRAM global-subarray ID
being channel-innermost), and the PUMA ``alloc`` path stripes a request's
tiles across channels in contiguous per-channel chunks: round-robin over
channels, worst-fit arena *within* the channel.  Block tables then spread
across channels, so the channel-parallel PUD/DMA substrate sees balanced
per-channel load; :meth:`TilePool.channel_occupancy` reports the balance.
The default ``n_channels=1`` keeps the original single-pool behaviour
bit-for-bit.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import random
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

from repro.robustness.errors import DoubleFree
from repro.robustness.faults import injected_alloc_miss

if TYPE_CHECKING:
    from repro.robustness.faults import FaultInjector
    from repro.robustness.journal import Journal

__all__ = ["TileHandle", "PoolStats", "TilePool"]


@dataclasses.dataclass
class TileHandle:
    """A logical buffer: an ordered list of global tile indices."""

    hid: int
    tiles: List[int]          # global tile index = arena * tiles_per_arena + slot

    def __len__(self) -> int:
        return len(self.tiles)

    def runs(self) -> List[tuple]:
        """Maximal (start, length) runs of consecutive tile indices."""
        out = []
        i = 0
        while i < len(self.tiles):
            j = i
            while (
                j + 1 < len(self.tiles) and self.tiles[j + 1] == self.tiles[j] + 1
            ):
                j += 1
            out.append((self.tiles[i], j - i + 1))
            i = j + 1
        return out

    def contiguous_run_fraction(self) -> float:
        """Fraction of tile->tile transitions that stay contiguous.

        1.0 means the whole handle is one DMA descriptor; 0.0 means every
        tile needs its own gather — the TPU analogue of 0 % PUD execution.
        """
        if len(self.tiles) <= 1:
            return 1.0
        good = sum(
            1
            for a, b in zip(self.tiles, self.tiles[1:])
            if b == a + 1
        )
        return good / (len(self.tiles) - 1)


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    align_hits: int = 0
    align_misses: int = 0
    failed: int = 0
    injected_misses: int = 0   # transient misses forced by the fault injector


class TilePool:
    """Host-side allocator over a (n_arenas x tiles_per_arena) tile grid."""

    POLICIES = ("puma", "first_fit", "random")

    def __init__(
        self,
        n_arenas: int,
        tiles_per_arena: int,
        policy: str = "puma",
        seed: int = 0,
        n_channels: int = 1,
        injector: Optional["FaultInjector"] = None,
        journal: Optional["Journal"] = None,
    ):
        assert policy in self.POLICIES, policy
        assert n_channels >= 1 and n_arenas % n_channels == 0, (
            f"n_arenas={n_arenas} must be a multiple of n_channels={n_channels}"
        )
        self.n_arenas = n_arenas
        self.tiles_per_arena = tiles_per_arena
        self.policy = policy
        self.n_channels = n_channels
        self._next_channel = 0
        self.rng = random.Random(seed)
        # free slots per arena kept sorted ascending so contiguous runs pop
        # from the front; PUMA's ordered array is the lazy max-heap below.
        self._free: List[List[int]] = [
            list(range(tiles_per_arena)) for _ in range(n_arenas)
        ]
        self._heap: List[tuple] = [
            (-tiles_per_arena, a) for a in range(n_arenas)
        ]
        heapq.heapify(self._heap)
        # per-channel worst-fit heaps (arena % n_channels = owning channel)
        self._heap_ch: List[List[tuple]] = [
            [(-tiles_per_arena, a) for a in range(c, n_arenas, n_channels)]
            for c in range(n_channels)
        ]
        for h in self._heap_ch:
            heapq.heapify(h)
        self._handles: Dict[int, TileHandle] = {}
        self._next_hid = 1
        self.stats = PoolStats()
        #: fault injector consulted on alloc/extend (transient device-pool
        #: misses — what drives the serving engine's preemption path).
        self.injector = injector
        #: crash-consistency journal — records every alloc/extend/free
        #: outcome (actual tile placements) for forced bit-exact replay.
        self.journal = journal

    def _injected_miss(self) -> bool:
        """Shared hook — see :func:`repro.robustness.faults.injected_alloc_miss`."""
        return injected_alloc_miss(self.injector, self.stats, "failed")

    # -- bookkeeping ---------------------------------------------------------
    @property
    def total_tiles(self) -> int:
        return self.n_arenas * self.tiles_per_arena

    def free_tiles(self) -> int:
        return sum(len(f) for f in self._free)

    def _push_count(self, arena: int) -> None:
        entry = (-len(self._free[arena]), arena)
        heapq.heappush(self._heap, entry)
        if self.n_channels > 1:
            heapq.heappush(self._heap_ch[arena % self.n_channels], entry)

    def _worst_fit_arena(self, channel: Optional[int] = None) -> Optional[int]:
        if channel is None or self.n_channels == 1:
            heap = self._heap
        else:
            heap = self._heap_ch[channel]
        while heap:
            neg, a = heap[0]
            if len(self._free[a]) == -neg and -neg > 0:
                return a
            heapq.heappop(heap)
        return None

    def _take_slot(self, arena: int, slot: Optional[int] = None) -> Optional[int]:
        free = self._free[arena]
        if not free:
            return None
        if slot is None:
            s = free.pop(0)
        else:
            # adjacent-slot request from alloc_align
            i = bisect.bisect_left(free, slot)
            if i == len(free) or free[i] != slot:
                return None
            free.pop(i)
            s = slot
        self._push_count(arena)
        return arena * self.tiles_per_arena + s

    def _runs_of(self, arena: int) -> List[tuple]:
        """(start_index_in_free, start_slot, length) maximal runs, ascending."""
        free = self._free[arena]
        out = []
        i = 0
        while i < len(free):
            j = i
            while j + 1 < len(free) and free[j + 1] == free[j] + 1:
                j += 1
            out.append((i, free[i], j - i + 1))
            i = j + 1
        return out

    def _take_run(self, arena: int, want: int) -> List[int]:
        """Run-aware take (beyond-paper TPU refinement): prefer the smallest
        free run that satisfies ``want`` (best-fit over runs, so long runs
        survive for long allocations), else the longest available run."""
        runs = self._runs_of(arena)
        if not runs:
            return []
        fitting = [r for r in runs if r[2] >= want]
        idx, slot, length = (
            min(fitting, key=lambda r: r[2])
            if fitting
            else max(runs, key=lambda r: r[2])
        )
        n = min(want, length)
        del self._free[arena][idx : idx + n]
        self._push_count(arena)
        base = arena * self.tiles_per_arena
        return [base + slot + i for i in range(n)]

    def _global_to_arena(self, tile: int) -> int:
        return tile // self.tiles_per_arena

    def _register(self, tiles: List[int]) -> TileHandle:
        """Wrap freshly taken tiles in a live handle (+ journal the outcome)."""
        h = TileHandle(self._next_hid, tiles)
        self._next_hid += 1
        self._handles[h.hid] = h
        if self.journal is not None:
            self.journal.append("alloc", hid=h.hid, tiles=list(tiles))
        self.stats.allocs += 1
        return h

    # -- PUMA API ------------------------------------------------------------
    def alloc(self, n_tiles: int) -> Optional[TileHandle]:
        if self._injected_miss():
            return None
        if n_tiles > self.free_tiles():
            self.stats.failed += 1
            return None
        tiles: List[int] = []
        if self.policy == "puma":
            if self.n_channels > 1:
                # channel-striped PUMA: hand each channel a contiguous chunk
                # (round-robin over channels, worst-fit arena within), so the
                # handle's blocks spread evenly over the channel-parallel
                # substrate while each chunk stays one DMA descriptor.
                chunk = -(-n_tiles // self.n_channels)
                while len(tiles) < n_tiles:
                    got: List[int] = []
                    for _ in range(self.n_channels):
                        ch = self._next_channel
                        self._next_channel = (ch + 1) % self.n_channels
                        a = self._worst_fit_arena(channel=ch)
                        if a is None:
                            continue
                        got = self._take_run(a, min(chunk, n_tiles - len(tiles)))
                        if got:
                            break
                    if not got:  # cannot happen given the free_tiles gate
                        for t in tiles:
                            self._give_back(t)
                        self.stats.failed += 1
                        return None
                    tiles.extend(got)
            else:
                while len(tiles) < n_tiles:
                    a = self._worst_fit_arena()
                    got = self._take_run(a, n_tiles - len(tiles))
                    if not got:  # arena raced empty via stale heap entry
                        continue
                    tiles.extend(got)
        elif self.policy == "first_fit":
            for a in range(self.n_arenas):
                while len(tiles) < n_tiles:
                    t = self._take_slot(a)
                    if t is None:
                        break
                    tiles.append(t)
                if len(tiles) == n_tiles:
                    break
        else:  # random — models a fragmented generic allocator
            candidates = [
                a for a in range(self.n_arenas) if self._free[a]
            ]
            while len(tiles) < n_tiles:
                a = self.rng.choice(candidates)
                free = self._free[a]
                s = free.pop(self.rng.randrange(len(free)))
                self._push_count(a)
                tiles.append(a * self.tiles_per_arena + s)
                if not free:
                    candidates.remove(a)
        return self._register(tiles)

    def alloc_align(self, n_tiles: int, hint: TileHandle) -> Optional[TileHandle]:
        if hint.hid not in self._handles:
            self.stats.failed += 1
            return None
        if self._injected_miss():
            return None
        if n_tiles > self.free_tiles():
            self.stats.failed += 1
            return None
        tiles: List[int] = []
        for k in range(n_tiles):
            placed = None
            if k < len(hint.tiles):
                arena = self._global_to_arena(hint.tiles[k])
            elif tiles:
                # beyond the hint's length: stay local to the handle so far
                arena = self._global_to_arena(tiles[-1])
            else:
                arena = None
            if arena is not None:
                # strongest alignment: the *same slot offset* neighbourhood —
                # try the slot right after the previous placed tile first so
                # the new handle is itself contiguous, then any slot in the
                # hinted arena.
                if tiles and self._global_to_arena(tiles[-1]) == arena:
                    want = tiles[-1] % self.tiles_per_arena + 1
                    if want < self.tiles_per_arena:
                        placed = self._take_slot(arena, want)
                if placed is None:
                    placed = self._take_slot(arena)
                if placed is not None:
                    self.stats.align_hits += 1
            if placed is None:
                self.stats.align_misses += 1
                a = self._worst_fit_arena()
                if a is None:
                    for t in tiles:
                        self._give_back(t)
                    self.stats.failed += 1
                    return None
                placed = self._take_slot(a)
            tiles.append(placed)
        return self._register(tiles)

    def extend(self, handle: TileHandle, n_more: int = 1) -> bool:
        """Grow a live handle (KV-cache decode step): prefer the slot after
        the handle's last tile, then same arena, then worst-fit."""
        if handle.hid not in self._handles:
            return False
        if self._injected_miss():
            return False
        for _ in range(n_more):
            placed = None
            if handle.tiles:
                last = handle.tiles[-1]
                arena = self._global_to_arena(last)
                want = last % self.tiles_per_arena + 1
                if want < self.tiles_per_arena:
                    placed = self._take_slot(arena, want)
                if placed is None and self.policy == "puma":
                    placed = self._take_slot(arena)
                    if placed is not None:
                        self.stats.align_hits += 1
            if placed is None:
                if self.policy == "puma":
                    a = self._worst_fit_arena()
                    self.stats.align_misses += 1
                elif self.policy == "first_fit":
                    a = next(
                        (i for i in range(self.n_arenas) if self._free[i]), None
                    )
                else:
                    cand = [i for i in range(self.n_arenas) if self._free[i]]
                    a = self.rng.choice(cand) if cand else None
                if a is None:
                    return False
                if self.policy == "random":
                    free = self._free[a]
                    s = free.pop(self.rng.randrange(len(free)))
                    self._push_count(a)
                    placed = a * self.tiles_per_arena + s
                else:
                    placed = self._take_slot(a)
            handle.tiles.append(placed)
            if self.journal is not None:
                self.journal.append("extend", hid=handle.hid, tile=placed)
        return True

    def _give_back(self, tile: int) -> None:
        arena = self._global_to_arena(tile)
        slot = tile % self.tiles_per_arena
        free = self._free[arena]
        bisect.insort(free, slot)  # keep sorted so runs pop from the front
        self._push_count(arena)

    def free(self, handle: TileHandle) -> None:
        if handle.hid not in self._handles:
            raise DoubleFree(f"handle {handle.hid} is not live", hid=handle.hid)
        del self._handles[handle.hid]
        for t in handle.tiles:
            self._give_back(t)
        if self.journal is not None:
            self.journal.append("free", hid=handle.hid)
        self.stats.frees += 1

    # -- metrics ---------------------------------------------------------------
    def channel_occupancy(self) -> Dict[str, object]:
        """Per-channel used/free tile counts + load balance.

        ``balance`` is mean/max of per-channel used tiles (1.0 = perfectly
        striped block tables, 1/C = all live blocks on one channel).
        """
        used = [0] * self.n_channels
        free = [0] * self.n_channels
        for a, fr in enumerate(self._free):
            c = a % self.n_channels
            free[c] += len(fr)
            used[c] += self.tiles_per_arena - len(fr)
        mx = max(used) if used else 0
        balance = (sum(used) / len(used)) / mx if mx > 0 else 1.0
        return {
            "channels": self.n_channels,
            "used_tiles": used,
            "free_tiles": free,
            "balance": float(balance),
        }

    def fragmentation(self) -> float:
        """1 - (largest free run / total free) across the pool."""
        total = self.free_tiles()
        if total == 0:
            return 0.0
        best = 0
        for a, free in enumerate(self._free):
            run = 0
            prev = None
            for s in free:
                run = run + 1 if prev is not None and s == prev + 1 else 1
                best = max(best, run)
                prev = s
        return 1.0 - best / total
