"""DRAM geometry, address interleaving, and physical-address decoding.

This module models the information PUMA obtains from the platform:

  (i)  the DRAM organization (row/column/bank/subarray sizes) — paper §2(i);
  (ii) the DRAM interleaving scheme, i.e. which physical-address bits select
       channel / rank / bank / row / column, optionally XOR-folded — the
       paper obtains this from an open-firmware device tree (§2(ii)) or by
       reverse engineering (RowHammer-RE literature [143-145]).

Terminology (paper footnote 1): a typical subarray has 1024 rows of 1024
columns per chip => 1 MB per subarray per chip.  At *rank* level (the
granularity the memory controller reads/writes), one logical "row" spans all
chips sharing a chip-select: with 8 x8 chips, a rank-row is 8 KB.  PUMA's
"memory region" is one rank-row — the granularity at which PUD operands must
be aligned and co-located.

Two interleaving schemes are provided:

* ``BANK_REGION_SCHEME`` (default — the paper's abstraction): consecutive
  physical addresses fill a whole row, then consecutive rows of the same
  bank, then banks/ranks/channels.  An aligned rank-row-sized PA chunk maps
  to exactly one (channel, rank, bank, subarray) — the global subarray ID is
  the concatenation ("OR of mask bits", §2) of those fields.

* ``CACHELINE_INTERLEAVED_SCHEME``: the common performance policy that
  stripes consecutive cache lines across channels and banks.  Here an
  aligned region is a *stripe* across banks at one row index; operands at
  equal region offsets still land in the same (channel, bank, column)
  byte-for-byte, so PUD executability reduces to matching subarray stripes.
  The same decode logic covers it because region bases zero the low
  channel/bank fields.

Decode fast path
----------------

``AddressMap`` precomputes every field's shift and mask at construction, so
scalar :meth:`AddressMap.decode` is straight bit arithmetic and
:meth:`AddressMap.region_subarrays` decodes a whole ``np.ndarray`` of
physical addresses with a handful of vectorized bit operations — the
translation layer the PUD planner, the PUMA pre-allocator, and the
benchmarks all batch through.  :meth:`AddressMap.region_subarray_table`
additionally memoizes the full region→global-subarray map (one ``int32``
per region, built lazily on first use) for O(1) repeated scalar lookups.
The scalar :meth:`AddressMap.region_subarray` keeps the original
one-address-at-a-time decode; property tests assert the two paths agree
under every interleave scheme.

Channel view
------------

The PUD executor is channel-parallel (one memory controller per channel,
HBM-PIM style — see :mod:`repro.core.controller`), so the decode layer also
exposes the *channel* structure of the global-subarray space:

* :meth:`AddressMap.region_coords` — one vectorized pass producing the
  ``(channel, rank, bank, subarray)`` arrays for a batch of region PAs;
* :meth:`AddressMap.region_channels` — just the owning-channel array;
* :meth:`AddressMap.channel_of_subarray` — recover the channel from a
  global subarray ID without re-decoding.  The global ID is built
  channel-innermost (``((sa·B + bank)·R + rank)·C + channel``), so the
  channel is simply ``gsa % channels`` — scalar ints and numpy arrays both
  work.

Under ``BANK_REGION_SCHEME`` every region is owned by exactly one channel
and the PUD executor can run regions of different channels concurrently.
Under ``CACHELINE_INTERLEAVED_SCHEME`` a region *is* a stripe across all
channels (the channel bits sit below the region boundary and decode to 0),
so each row op already engages every channel at once and the partitioned
executor degenerates to the single-queue model — exactly the hardware
behaviour.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = [
    "DramGeometry",
    "InterleaveScheme",
    "DramCoord",
    "AddressMap",
    "DEFAULT_GEOMETRY",
    "BANK_REGION_SCHEME",
    "CACHELINE_INTERLEAVED_SCHEME",
    "default_map",
]


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def _log2(x: int) -> int:
    assert _is_pow2(x), f"{x} is not a power of two"
    return x.bit_length() - 1


@dataclasses.dataclass(frozen=True)
class DramGeometry:
    """Physical organization of the DRAM devices behind one memory node.

    Defaults follow the paper's evaluated system: 8 GB total, and footnote 1's
    "typical subarray" of 1024 rows x 1024 columns = 1 MB.  (The QEMU RISC-V
    target is modeled as one channel / one rank of x64 devices, so the
    chip-level and rank-level row coincide at 1 KB.)
    """

    channels: int = 1
    ranks_per_channel: int = 1
    banks_per_rank: int = 8
    subarrays_per_bank: int = 1024
    rows_per_subarray: int = 1024       # paper footnote 1
    row_bytes_per_chip: int = 1024      # 1024 columns x 8 bits (paper fn. 1)
    chips_per_rank: int = 1

    @property
    def row_bytes(self) -> int:
        """Rank-level row size = PUMA memory-region size (8 KB default)."""
        return self.row_bytes_per_chip * self.chips_per_rank

    @property
    def rows_per_bank(self) -> int:
        return self.subarrays_per_bank * self.rows_per_subarray

    @property
    def subarray_bytes(self) -> int:
        """Rank-level bytes held by one subarray (1 MB/chip x 8 chips)."""
        return self.rows_per_subarray * self.row_bytes

    @property
    def bank_bytes(self) -> int:
        return self.subarrays_per_bank * self.subarray_bytes

    @property
    def total_bytes(self) -> int:
        return (
            self.channels
            * self.ranks_per_channel
            * self.banks_per_rank
            * self.bank_bytes
        )

    @property
    def num_global_subarrays(self) -> int:
        return (
            self.channels
            * self.ranks_per_channel
            * self.banks_per_rank
            * self.subarrays_per_bank
        )

    @property
    def banks_per_channel(self) -> int:
        """Banks one channel's controller schedules across (rank x bank)."""
        return self.ranks_per_channel * self.banks_per_rank

    @property
    def subarrays_per_channel(self) -> int:
        """Global subarrays owned by one channel's controller."""
        return self.num_global_subarrays // self.channels

    @property
    def channel_bytes(self) -> int:
        return self.total_bytes // self.channels

    def __post_init__(self):
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if not _is_pow2(v):
                raise ValueError(f"DramGeometry.{f.name}={v} must be a power of 2")


@dataclasses.dataclass(frozen=True)
class DramCoord:
    channel: int
    rank: int
    bank: int
    subarray: int  # subarray index within the bank
    row: int       # row index within the subarray
    col: int       # byte offset within the rank-row

    def global_subarray(self, geo: DramGeometry) -> int:
        """Concatenated (channel, rank, bank, subarray) — the PUD locality unit.

        The paper builds this by OR-ing the masked channel/rank/bank/subarray
        bits of the physical address; since the fields occupy disjoint bit
        ranges this is exactly a concatenation.
        """
        g = self.subarray
        g = g * geo.banks_per_rank + self.bank
        g = g * geo.ranks_per_channel + self.rank
        g = g * geo.channels + self.channel
        return g


# Field names understood by InterleaveScheme.order, LSB -> MSB.
_FIELDS = ("col_lo", "col_hi", "channel", "bank", "rank", "row")


@dataclasses.dataclass(frozen=True)
class InterleaveScheme:
    """Which physical-address bit-fields select each DRAM coordinate.

    ``order`` lists fields from LSB to MSB.  ``row`` is the global row index
    within a bank; the subarray index is its high ``log2(subarrays_per_bank)``
    bits.  ``xor_row_into_bank`` models the common bank-XOR permutation
    (bank := bank_bits XOR low-row-bits) used by real controllers and
    recovered by RowHammer reverse-engineering; PUMA decodes through it.
    """

    order: Tuple[str, ...]
    col_lo_bytes: int = 64  # cache-line granule before the first split field
    xor_row_into_bank: bool = False

    def field_widths(self, geo: DramGeometry) -> List[Tuple[str, int]]:
        col_lo = min(self.col_lo_bytes, geo.row_bytes)
        widths = {
            "col_lo": _log2(col_lo),
            "col_hi": _log2(geo.row_bytes // col_lo),
            "channel": _log2(geo.channels),
            "bank": _log2(geo.banks_per_rank),
            "rank": _log2(geo.ranks_per_channel),
            "row": _log2(geo.rows_per_bank),
        }
        assert sorted(self.order) == sorted(_FIELDS), self.order
        return [(name, widths[name]) for name in self.order]


#: The paper's abstraction: rows of one bank are consecutive, so an aligned
#: rank-row chunk belongs to exactly one global subarray.
BANK_REGION_SCHEME = InterleaveScheme(
    order=("col_lo", "col_hi", "row", "bank", "rank", "channel")
)

#: Performance-oriented mapping: cache lines striped across channels/banks.
CACHELINE_INTERLEAVED_SCHEME = InterleaveScheme(
    order=("col_lo", "channel", "bank", "col_hi", "rank", "row")
)


class AddressMap:
    """Decodes physical addresses to DRAM coordinates under a scheme."""

    def __init__(self, geo: DramGeometry = None, scheme: InterleaveScheme = None):
        self.geo = geo or DEFAULT_GEOMETRY
        self.scheme = scheme or CACHELINE_INTERLEAVED_SCHEME
        self._fields = self.scheme.field_widths(self.geo)
        self._total_bits = sum(w for _, w in self._fields)
        if (1 << self._total_bits) != self.geo.total_bytes:
            raise ValueError(
                f"scheme covers 2^{self._total_bits} bytes but geometry has "
                f"{self.geo.total_bytes}"
            )
        # The PUD operand granularity: the smallest aligned PA chunk whose
        # bytes all share one row index — everything mapped below the row
        # field.  BANK_REGION: one rank-row.  CACHELINE_INTERLEAVED: the
        # row-*set* stripe (same row index across all banks/channels, which
        # the substrate operates bank-parallel).
        bits_below_row = 0
        for name, width in self._fields:
            if name == "row":
                break
            bits_below_row += width
        self._region_bytes = 1 << bits_below_row
        # Per-field shift/mask, computed once: decode becomes pure bit math.
        self._shifts = {}
        self._masks = {}
        shift = 0
        for name, width in self._fields:
            self._shifts[name] = shift
            self._masks[name] = (1 << width) - 1
            shift += width
        self._log_rows_per_sub = _log2(self.geo.rows_per_subarray)
        self._region_sa_table: Optional[np.ndarray] = None  # lazy memo

    @property
    def total_bytes(self) -> int:
        return self.geo.total_bytes

    def decode(self, pa: int) -> DramCoord:
        if not (0 <= pa < self.geo.total_bytes):
            raise ValueError(f"physical address {pa:#x} out of range")
        sh, mk = self._shifts, self._masks
        row_global = (pa >> sh["row"]) & mk["row"]
        bank = (pa >> sh["bank"]) & mk["bank"]
        if self.scheme.xor_row_into_bank:
            bank ^= row_global & (self.geo.banks_per_rank - 1)
        col = ((pa >> sh["col_lo"]) & mk["col_lo"]) | (
            ((pa >> sh["col_hi"]) & mk["col_hi"]) << mk["col_lo"].bit_length()
        )
        return DramCoord(
            channel=(pa >> sh["channel"]) & mk["channel"],
            rank=(pa >> sh["rank"]) & mk["rank"],
            bank=bank,
            subarray=row_global >> self._log_rows_per_sub,
            row=row_global & (self.geo.rows_per_subarray - 1),
            col=col,
        )

    # -- Region-level helpers (PUMA operates on rank-rows = memory regions) --

    @property
    def region_bytes(self) -> int:
        return self._region_bytes

    def region_is_aligned(self, pa: int) -> bool:
        """PUD operand rows must start exactly at a region boundary."""
        return pa % self._region_bytes == 0

    def region_subarray(self, pa: int) -> int:
        """Global subarray ID of the aligned region starting at ``pa``.

        For region-aligned bases the sub-region (column) fields are zero, so
        the decode yields the region's (channel, rank, bank, subarray) under
        BANK_REGION_SCHEME, and the subarray *stripe* under the cacheline-
        interleaved scheme — in both cases, equality of this ID across two
        aligned regions is exactly PUD operand compatibility.

        This is the scalar reference path (one full decode per call); batch
        callers should use :meth:`region_subarrays` and repeated scalar
        callers :meth:`region_subarray_table`.
        """
        return self.decode(pa).global_subarray(self.geo)

    def region_subarrays(self, pas: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`region_subarray` over an array of addresses.

        Pure bit operations on int64 arrays — no per-element Python.  The
        inputs need not be region-aligned; sub-region bits are simply ignored
        (they sit below the row/bank/rank/channel fields by construction).
        """
        pas = np.asarray(pas, dtype=np.int64)
        geo = self.geo
        sh, mk = self._shifts, self._masks
        row = (pas >> sh["row"]) & mk["row"]
        bank = (pas >> sh["bank"]) & mk["bank"]
        if self.scheme.xor_row_into_bank:
            bank = bank ^ (row & (geo.banks_per_rank - 1))
        rank = (pas >> sh["rank"]) & mk["rank"]
        chan = (pas >> sh["channel"]) & mk["channel"]
        sa = row >> self._log_rows_per_sub
        g = (sa * geo.banks_per_rank + bank) * geo.ranks_per_channel + rank
        return g * geo.channels + chan

    def region_coords(
        self, pas: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Batch decode of ``(channel, rank, bank, subarray)`` per region PA.

        One vectorized pass (same bit-ops as :meth:`region_subarrays`, fields
        kept separate instead of concatenated) — the view the per-channel
        controllers and the channel-striping allocators consume.  Sub-region
        bits are ignored, so inputs need not be region-aligned.
        """
        pas = np.asarray(pas, dtype=np.int64)
        sh, mk = self._shifts, self._masks
        row = (pas >> sh["row"]) & mk["row"]
        bank = (pas >> sh["bank"]) & mk["bank"]
        if self.scheme.xor_row_into_bank:
            bank = bank ^ (row & (self.geo.banks_per_rank - 1))
        rank = (pas >> sh["rank"]) & mk["rank"]
        chan = (pas >> sh["channel"]) & mk["channel"]
        sa = row >> self._log_rows_per_sub
        return chan, rank, bank, sa

    def region_channels(self, pas: np.ndarray) -> np.ndarray:
        """Owning channel of each region PA (vectorized).

        Under BANK_REGION_SCHEME this is the single channel that executes
        PUD ops on the region; under CACHELINE_INTERLEAVED_SCHEME region
        bases zero the channel bits, so every region reports channel 0 — a
        region there is a stripe across *all* channels and the channel-
        partitioned executor collapses to one queue by construction.
        """
        pas = np.asarray(pas, dtype=np.int64)
        return (pas >> self._shifts["channel"]) & self._masks["channel"]

    def channel_of_subarray(self, gsa):
        """Channel owning a global subarray ID (scalar int or ndarray).

        ``DramCoord.global_subarray`` concatenates channel-innermost, so the
        channel is the low ``log2(channels)`` bits — no re-decode needed.
        """
        return gsa % self.geo.channels

    def region_subarray_table(self) -> np.ndarray:
        """Memoized region-index → global-subarray lookup (int32, lazy).

        Built once per ``AddressMap`` via the batch decode; indexing it with
        ``pa // region_bytes`` answers repeated scalar queries (e.g. PUMA's
        aligned-allocation hint walk) without re-decoding.
        """
        if self._region_sa_table is None:
            n = self.geo.total_bytes // self._region_bytes
            rpas = np.arange(n, dtype=np.int64) * self._region_bytes
            self._region_sa_table = self.region_subarrays(rpas).astype(np.int32)
        return self._region_sa_table

    def region_range(self, pa: int, nbytes: int) -> Tuple[np.ndarray, np.ndarray]:
        """Batch form of :meth:`regions_in_range`: ``(region_pas, subarrays)``
        as int64 arrays for every full region inside ``[pa, pa + nbytes)``."""
        rb = self._region_bytes
        first = -(-pa // rb)  # ceil
        last = (pa + nbytes) // rb
        if last <= first:
            empty = np.empty(0, dtype=np.int64)
            return empty, empty
        # the scalar path range-checked every decode; keep failing loudly
        # rather than letting the bit-ops alias out-of-range addresses
        if first < 0 or last * rb > self.geo.total_bytes:
            raise ValueError(
                f"region range [{pa:#x}, {pa + nbytes:#x}) exceeds "
                f"{self.geo.total_bytes:#x} bytes of physical memory"
            )
        rpas = np.arange(first, last, dtype=np.int64) * rb
        return rpas, self.region_subarrays(rpas)

    def regions_in_range(self, pa: int, nbytes: int) -> List[Tuple[int, int]]:
        """(region_pa, global_subarray) for every full region in [pa, pa+n)."""
        rpas, sas = self.region_range(pa, nbytes)
        return list(zip(rpas.tolist(), sas.tolist()))


DEFAULT_GEOMETRY = DramGeometry()


def default_map() -> AddressMap:
    return AddressMap(DEFAULT_GEOMETRY, CACHELINE_INTERLEAVED_SCHEME)
