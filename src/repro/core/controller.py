"""Per-channel memory-controller model with PUD dispatch (HBM-PIM style).

Real PUD substrates get their headline gains from *bank-level parallelism*:
every channel has its own memory controller with its own request queue, and
the channels execute independently (the HBM-PIMulator exemplar instantiates
one ``IDRAMController`` per channel and broadcasts PIM requests across them;
MIMDRAM executes across many mats/banks concurrently).  This module gives
the repo that structure as an analytic queue model:

* :class:`ChannelController` — one channel's request queue, collapsed to a
  ``busy_until_ns`` frontier plus FR-FCFS-lite pricing:

  - **PUD bursts** are AAP (ACTIVATE-ACTIVATE-PRECHARGE) command sequences
    issued back to back; ``n_rows`` rows of op ``op`` cost
    ``n_rows * PudCostModel.pud_row_ns(op)`` once the channel is free.
  - **Normal accesses** are grouped by (bank, row) first — the "first-ready"
    half of FR-FCFS — so requests hitting an open row pay ``row_hit_ns``
    (CAS only) and row conflicts pay ``row_miss_ns`` (PRE+ACT+CAS).
  - **Mode switching**: the channel is either in normal ``SB``
    (single-bank) mode or ``PIM`` mode (the HBM-PIM SB/AB/PIM register
    dance); every transition costs ``mode_switch_ns``.  Interleaving PUD
    ops with reads/writes on one channel therefore pays visibly.

* :class:`DramController` — the device: one :class:`ChannelController` per
  channel of the :class:`~repro.core.dram.AddressMap`'s geometry.
  :meth:`DramController.dispatch_pud` partitions an op's row list by owning
  channel (``channel_of_subarray`` — one modulo, no re-decode) and enqueues
  each partition on its controller; the op completes at the **max** of the
  per-channel completion times, so a RowClone copy striped over 8 channels
  finishes ~8x faster while two ops contending for one channel serialize
  through its ``busy_until_ns`` frontier.

:meth:`DramController.occupancy_report` surfaces the new figure of merit:
per-channel busy time / PUD row counts and the load-balance ratio
(mean/max rows per channel; 1.0 = perfectly striped placement).

The model is deliberately state-light (no cycle-accurate timing): it only
needs to make channel contention and placement imbalance *visible* to the
cost model, the benchmarks, and the serving simulations.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dram import AddressMap

if TYPE_CHECKING:
    from repro.robustness.faults import FaultInjector

__all__ = [
    "ControllerConfig",
    "ChannelStats",
    "ChannelController",
    "PudDispatch",
    "DramController",
    "channel_row_counts",
]


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Timing knobs of one channel's controller (DDR-scale defaults)."""

    mode_switch_ns: float = 120.0   # SB <-> PIM mode register transition
    row_hit_ns: float = 15.0        # CAS on an already-open row (tCCD+tCL-ish)
    row_miss_ns: float = 50.0       # PRE + ACT + CAS on a row conflict
    cacheline_bytes: int = 64       # granularity of one normal access


@dataclasses.dataclass
class ChannelStats:
    pud_ops: int = 0
    pud_rows: int = 0
    mem_accesses: int = 0
    row_hits: int = 0
    row_misses: int = 0
    mode_switches: int = 0
    busy_ns: float = 0.0
    injected_stalls: int = 0       # fault-injected controller stalls
    injected_stall_ns: float = 0.0


class ChannelController:
    """One channel's request queue, collapsed to a completion frontier.

    Requests are priced in arrival order (FCFS across bursts); within one
    normal-access burst the (bank, row) grouping models FR-FCFS's row-hit
    reordering.  ``busy_until_ns`` is the time the channel next goes idle —
    enqueueing starts at ``max(now, busy_until_ns)``, which is exactly what
    makes contention between ops on the same channel visible.
    """

    SB = "SB"
    PIM = "PIM"

    def __init__(
        self,
        channel_id: int,
        cfg: Optional[ControllerConfig] = None,
        injector: Optional["FaultInjector"] = None,
    ):
        self.channel_id = channel_id
        self.cfg = cfg or ControllerConfig()
        self.busy_until_ns = 0.0
        self.mode = self.SB
        self._open_rows: Dict[int, int] = {}   # bank -> open row index
        self.stats = ChannelStats()
        #: fault injector: each dispatched burst may hit an injected stall
        #: (refresh storm / thermal throttle); None = never.
        self.injector = injector

    # -- internals ----------------------------------------------------------
    def _begin(self, now_ns: float) -> float:
        return max(now_ns, self.busy_until_ns)

    def _injected_stall(self, t: float) -> float:
        if self.injector is not None:
            stall = self.injector.stall_ns()
            if stall:
                self.stats.injected_stalls += 1
                self.stats.injected_stall_ns += stall
                t += stall
        return t

    def _switch_mode(self, mode: str, t: float) -> float:
        if self.mode != mode:
            self.mode = mode
            self.stats.mode_switches += 1
            t += self.cfg.mode_switch_ns
        return t

    def _finish(self, start: float, t: float) -> float:
        self.busy_until_ns = t
        self.stats.busy_ns += t - start
        return t

    # -- PUD command bursts -------------------------------------------------
    def enqueue_pud(self, n_rows: int, row_ns: float, now_ns: float = 0.0) -> float:
        """Queue ``n_rows`` AAP sequences of ``row_ns`` each; returns the
        completion time.  The rows of one burst issue back to back (the PUD
        driver batches a whole op's command stream per channel)."""
        start = self._begin(now_ns)
        if n_rows <= 0:
            return start
        t = self._switch_mode(self.PIM, start)
        t += n_rows * row_ns
        t = self._injected_stall(t)
        self.stats.pud_ops += 1
        self.stats.pud_rows += n_rows
        # PUD ops open/close rows themselves; the row buffer is left closed.
        self._open_rows.clear()
        return self._finish(start, t)

    def peek_pud(self, n_rows: int, row_ns: float, now_ns: float = 0.0) -> float:
        """Completion time :meth:`enqueue_pud` *would* return — no mutation.
        The adaptive PUD driver uses this to decide offload vs CPU fallback
        before committing the command stream to the queue."""
        start = self._begin(now_ns)
        if n_rows <= 0:
            return start
        t = start + (self.cfg.mode_switch_ns if self.mode != self.PIM else 0.0)
        return t + n_rows * row_ns

    # -- normal reads/writes (FR-FCFS-lite) ---------------------------------
    def enqueue_accesses(
        self,
        bank_rows: Sequence[Tuple[int, int]],
        now_ns: float = 0.0,
    ) -> float:
        """Queue one burst of normal accesses, each a ``(bank, row)`` pair.

        The burst is grouped by (bank, row) — FR-FCFS serves row hits first —
        so each distinct row pays one ``row_miss_ns`` activation (unless it
        is already open in the bank's row buffer) and every further access
        to it pays ``row_hit_ns``.
        """
        start = self._begin(now_ns)
        if not len(bank_rows):
            return start
        t = self._switch_mode(self.SB, start)
        groups: Dict[Tuple[int, int], int] = {}
        for bank, row in bank_rows:
            groups[(bank, row)] = groups.get((bank, row), 0) + 1
        hits = misses = 0
        for (bank, row), n in groups.items():
            if self._open_rows.get(bank) == row:
                hits += n
            else:
                misses += 1
                hits += n - 1
                self._open_rows[bank] = row
        t += hits * self.cfg.row_hit_ns + misses * self.cfg.row_miss_ns
        t = self._injected_stall(t)
        self.stats.mem_accesses += len(bank_rows)
        self.stats.row_hits += hits
        self.stats.row_misses += misses
        return self._finish(start, t)


@dataclasses.dataclass
class PudDispatch:
    """Outcome of dispatching one PUD op across the channels."""

    start_ns: float
    done_ns: float
    rows_per_channel: List[int]

    @property
    def latency_ns(self) -> float:
        return self.done_ns - self.start_ns

    @property
    def balance(self) -> float:
        """mean/max rows over *active* channels plus idle ones: 1.0 means the
        op's rows were spread evenly over every channel."""
        rows = np.asarray(self.rows_per_channel, dtype=np.float64)
        mx = rows.max() if rows.size else 0.0
        return float(rows.mean() / mx) if mx > 0 else 1.0


def channel_row_counts(
    row_subarrays: np.ndarray, amap: AddressMap
) -> np.ndarray:
    """Rows per owning channel for an array of global-subarray IDs.

    One ``bincount`` over ``gsa % channels`` — the vectorized partition the
    planner, the controller, and the benchmarks share.  ``-1`` entries
    (non-PUD rows) must be filtered by the caller.
    """
    chans = np.asarray(row_subarrays, dtype=np.int64) % amap.geo.channels
    return np.bincount(chans, minlength=amap.geo.channels)


class DramController:
    """One :class:`ChannelController` per channel of ``amap``'s geometry."""

    def __init__(
        self,
        amap: AddressMap,
        cfg: Optional[ControllerConfig] = None,
        injector: Optional["FaultInjector"] = None,
        recorder=None,
    ):
        self.amap = amap
        self.cfg = cfg or ControllerConfig()
        self.channels = [
            ChannelController(c, self.cfg, injector)
            for c in range(amap.geo.channels)
        ]
        self.now_ns = 0.0   # dispatch frontier (advances with completions)
        #: trace recorder (:class:`repro.trace.record.TraceRecorder`, duck-
        #: typed — only ``emit`` is used): every dispatched PUD burst /
        #: access burst lands in the trace with its per-channel shape and
        #: completion times.  None = no tracing overhead.
        self.recorder = recorder

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    # -- PUD ----------------------------------------------------------------
    def dispatch_pud(
        self,
        row_subarrays: np.ndarray,
        row_ns: float,
        now_ns: Optional[float] = None,
    ) -> PudDispatch:
        """Execute one PUD op whose rows live in ``row_subarrays`` (global
        subarray IDs, one per row).  Rows are partitioned by owning channel
        and enqueued per controller; the op completes at the max of the
        per-channel completion times."""
        now = self.now_ns if now_ns is None else now_ns
        counts = channel_row_counts(row_subarrays, self.amap)
        done = now
        for c, n in enumerate(counts.tolist()):
            if n:
                done = max(done, self.channels[c].enqueue_pud(n, row_ns, now))
        self.now_ns = max(self.now_ns, done)
        if self.recorder is not None:
            self.recorder.emit(
                "ctrl_pud",
                rows_per_channel=counts.tolist(), row_ns=float(row_ns),
                start=float(now), done=float(done),
            )
        return PudDispatch(now, done, counts.tolist())

    def peek_pud(
        self,
        row_subarrays: np.ndarray,
        row_ns: float,
        now_ns: Optional[float] = None,
    ) -> PudDispatch:
        """Queue-state-aware estimate of :meth:`dispatch_pud` — no mutation."""
        now = self.now_ns if now_ns is None else now_ns
        counts = channel_row_counts(row_subarrays, self.amap)
        done = now
        for c, n in enumerate(counts.tolist()):
            if n:
                done = max(done, self.channels[c].peek_pud(n, row_ns, now))
        return PudDispatch(now, done, counts.tolist())

    # -- normal traffic ------------------------------------------------------
    def dispatch_accesses(
        self, pas: np.ndarray, now_ns: Optional[float] = None
    ) -> float:
        """Price a burst of normal cacheline accesses at physical addresses
        ``pas``: partition by channel, FR-FCFS-lite within each.  Returns the
        burst completion time (max over channels)."""
        now = self.now_ns if now_ns is None else now_ns
        pas = np.asarray(pas, dtype=np.int64)
        if pas.size == 0:
            return now
        chan, rank, bank, sa = self.amap.region_coords(pas)
        # rank folds into the bank index: one controller schedules rank*bank
        geo = self.amap.geo
        bank_ids = rank * geo.banks_per_rank + bank
        rows = (pas >> self.amap._shifts["row"]) & self.amap._masks["row"]
        done = now
        for c in range(self.n_channels):
            m = chan == c
            if not m.any():
                continue
            pairs = list(zip(bank_ids[m].tolist(), rows[m].tolist()))
            done = max(done, self.channels[c].enqueue_accesses(pairs, now))
        self.now_ns = max(self.now_ns, done)
        if self.recorder is not None:
            # (channel, bank, row) triples, not raw PAs: the replay executor
            # re-queues them without needing the address map.
            self.recorder.emit(
                "ctrl_access",
                channels=self.n_channels,
                accesses=[
                    [int(c), int(b), int(r)]
                    for c, b, r in zip(
                        chan.tolist(), bank_ids.tolist(), rows.tolist()
                    )
                ],
                start=float(now), done=float(done),
            )
        return done

    # -- compaction / migration traffic ---------------------------------------
    def dispatch_migration(
        self,
        rowclone_subarrays: np.ndarray,
        row_ns: float,
        cpu_pas: Optional[np.ndarray] = None,
        now_ns: Optional[float] = None,
    ) -> float:
        """Queue one compaction pass's data movement on the channels.

        ``rowclone_subarrays`` — one global-subarray ID per same-subarray row
        copy: the substrate executes these in DRAM (RowClone FPM), so they
        enqueue as a PUD burst per owning channel.  ``cpu_pas`` — cacheline
        PAs touched by the cross-subarray copies the substrate cannot do
        (read at the source + write at the destination): they enqueue as
        normal FR-FCFS accesses, paying the SB<->PIM mode switch against any
        interleaved PUD traffic.  Returns the pass completion time; the
        channels stay busy until then, which is how background compaction
        competes with live traffic in the cost model.
        """
        now = self.now_ns if now_ns is None else now_ns
        done = now
        sas = np.asarray(rowclone_subarrays, dtype=np.int64)
        if sas.size:
            done = max(done, self.dispatch_pud(sas, row_ns, now).done_ns)
        if cpu_pas is not None and len(cpu_pas):
            done = max(done, self.dispatch_accesses(cpu_pas, now))
        self.now_ns = max(self.now_ns, done)
        return done

    # -- metrics -------------------------------------------------------------
    def occupancy_report(self) -> Dict[str, object]:
        """Per-channel occupancy + load balance — the channel figure of merit.

        ``busy_fraction`` is each channel's busy time over the makespan
        (``now_ns``); ``pud_row_balance`` is mean/max of per-channel PUD row
        counts (1.0 = perfectly striped placement, 1/C = everything on one
        channel)."""
        busy = [ch.stats.busy_ns for ch in self.channels]
        rows = np.asarray([ch.stats.pud_rows for ch in self.channels], float)
        span = self.now_ns
        mx = rows.max() if rows.size else 0.0
        return {
            "channels": self.n_channels,
            "makespan_ns": span,
            "busy_ns": busy,
            "busy_fraction": [b / span if span > 0 else 0.0 for b in busy],
            "pud_rows": rows.astype(int).tolist(),
            "pud_row_balance": float(rows.mean() / mx) if mx > 0 else 1.0,
            "mode_switches": [ch.stats.mode_switches for ch in self.channels],
            "injected_stalls": [ch.stats.injected_stalls for ch in self.channels],
            "injected_stall_ns": [
                ch.stats.injected_stall_ns for ch in self.channels
            ],
        }
