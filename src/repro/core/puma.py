"""PUMA: subarray-aware lazy allocation for Processing-Using-Memory (paper §2).

Faithful functional reproduction of the kernel module:

* ``pim_preallocate(n)`` — reserve ``n`` huge pages into the PUD pool; split
  each into rank-row-sized *memory regions*; index every region by its
  global subarray ID using the DRAM interleave decode (:mod:`repro.core.dram`).
* ``pim_alloc(size)`` — worst-fit over the *ordered array* of per-subarray
  free-region counts (paper: a buddy-allocator-style ordered array [146]):
  take regions from the subarray with the most free regions, spilling to the
  next-largest until satisfied.  The returned object is virtually contiguous
  (the kernel re-mmaps scattered regions; here the Allocation's extents model
  exactly that mapping).
* ``pim_alloc_align(size, hint)`` — walk the hint allocation's regions and
  place region *k* of the new allocation in the *same subarray* as region
  *k* of the hint, falling back to worst-fit when that subarray is full
  (paper §2 "Aligned Allocation", steps 1-5).
* an *allocation hashmap* keyed by virtual address tracks live allocations
  so future ``pim_alloc_align`` calls can find their hint.

``pim_free`` is added beyond the paper so that long-running property tests
and the serving integration can recycle the pool.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.core.allocators import HUGE_PAGE, Allocation, Extent, PhysicalMemory
from repro.core.dram import AddressMap

__all__ = ["PumaStats", "PumaAllocator"]


@dataclasses.dataclass
class PumaStats:
    preallocated_regions: int = 0
    live_allocations: int = 0
    regions_in_use: int = 0
    align_hits: int = 0      # regions placed in the hinted subarray
    align_misses: int = 0    # worst-fit fallbacks during pim_alloc_align
    failed_allocs: int = 0


class _OrderedArray:
    """Per-subarray free-region bookkeeping with worst-fit selection.

    The paper uses "an ordered array ... similar to the Linux buddy
    allocator, where each entry represents the number of memory regions in a
    single subarray".  We keep (a) a free-list per subarray and (b) a lazy
    max-heap over (count, subarray) for O(log S) worst-fit.

    With ``channels > 1`` the same bookkeeping is additionally sliced per
    channel (a subarray's channel is ``sa % channels`` — the global ID is
    channel-innermost): one lazy max-heap and one running total per channel,
    so :meth:`worst_fit_subarray` can answer "emptiest subarray *of channel
    c*" in O(log S) for the channel-striping allocation path.
    """

    def __init__(self, channels: int = 1):
        self.channels = channels
        self.free: Dict[int, List[int]] = {}   # subarray -> region PAs (LIFO)
        self._heap: List[tuple] = []           # (-count, subarray), lazy
        self._heap_ch: List[List[tuple]] = [[] for _ in range(channels)]
        self._total = 0                        # running free-region count
        self._total_ch = [0] * channels

    def _push(self, subarray: int) -> None:
        entry = (-len(self.free.get(subarray, ())), subarray)
        heapq.heappush(self._heap, entry)
        if self.channels > 1:
            heapq.heappush(self._heap_ch[subarray % self.channels], entry)

    def add_region(self, subarray: int, pa: int) -> None:
        lst = self.free.setdefault(subarray, [])
        lst.append(pa)
        self._push(subarray)
        self._total += 1
        self._total_ch[subarray % self.channels] += 1

    def add_regions(self, subarrays: np.ndarray, pas: np.ndarray) -> None:
        """Bulk insert: group by subarray, extend each free list once, and
        push ONE heap entry per touched subarray instead of one per region."""
        if len(pas) == 0:
            return
        order = np.argsort(subarrays, kind="stable")
        sas = np.asarray(subarrays)[order]
        ps = np.asarray(pas)[order]
        starts = np.flatnonzero(np.r_[True, sas[1:] != sas[:-1]])
        stops = np.r_[starts[1:], len(sas)]
        for start, stop in zip(starts.tolist(), stops.tolist()):
            sa = int(sas[start])
            lst = self.free.setdefault(sa, [])
            lst.extend(ps[start:stop].tolist())
            self._push(sa)
        self._total += len(ps)
        if self.channels > 1:
            counts = np.bincount(
                sas % self.channels, minlength=self.channels
            )
            for c in range(self.channels):
                self._total_ch[c] += int(counts[c])
        else:
            self._total_ch[0] += len(ps)

    def take_from(self, subarray: int) -> Optional[int]:
        lst = self.free.get(subarray)
        if not lst:
            return None
        pa = lst.pop()
        self._push(subarray)
        self._total -= 1
        self._total_ch[subarray % self.channels] -= 1
        return pa

    def worst_fit_subarray(self, channel: Optional[int] = None) -> Optional[int]:
        """Subarray with the largest number of free regions (lazy heap);
        restricted to one channel's subarrays when ``channel`` is given."""
        # channels == 1: the global view IS channel 0's view (and _push
        # skips the per-channel heaps to keep preallocation cheap)
        if channel is None or self.channels == 1:
            heap = self._heap
        else:
            heap = self._heap_ch[channel]
        while heap:
            neg, sa = heap[0]
            if len(self.free.get(sa, ())) == -neg and -neg > 0:
                return sa
            heapq.heappop(heap)  # stale entry
        return None

    def total_free(self, channel: Optional[int] = None) -> int:
        return self._total if channel is None else self._total_ch[channel]

    def channel_free(self) -> List[int]:
        return list(self._total_ch)

    def free_counts(self) -> Dict[int, int]:
        return {sa: len(v) for sa, v in self.free.items() if v}


class PumaAllocator:
    name = "puma"

    def __init__(
        self,
        mem: PhysicalMemory,
        amap: Optional[AddressMap] = None,
        *,
        stripe_channels: bool = False,
    ):
        self.mem = mem
        self.amap = amap or mem.amap
        self.region_bytes = self.amap.region_bytes
        self.n_channels = self.amap.geo.channels
        #: stripe first allocations round-robin across channels (worst-fit
        #: *within* each channel) so consecutive logical rows land on
        #: different channels and the channel-parallel PUD executor scales.
        #: Off by default — and a no-op at channels=1 — so the paper's
        #: single-channel placement is untouched.
        self.stripe_channels = stripe_channels
        self._next_channel = 0
        self._ordered = _OrderedArray(self.n_channels)
        self._used_per_channel = np.zeros(self.n_channels, dtype=np.int64)
        self._allocations: Dict[int, Allocation] = {}  # the allocation hashmap
        self._regions_of: Dict[int, List[int]] = {}    # va -> region PAs
        self._va_next = 0x7000_0000_0000
        self.stats = PumaStats()

    # -- 1) pre-allocation (paper step (1)) ---------------------------------
    def pim_preallocate(self, n_huge_pages: int) -> int:
        """Populate the PUD pool; returns the number of regions indexed.

        Every huge page's regions are decoded in one numpy batch (huge pages
        are region-aligned, so the region set is a plain arange) and inserted
        grouped-by-subarray — no per-region Python calls.
        """
        hps = self.mem.take_huge(n_huge_pages)
        if not hps:
            return 0
        rb = self.region_bytes
        per_hp = np.arange(HUGE_PAGE // rb, dtype=np.int64) * rb
        rpas = (np.asarray(hps, dtype=np.int64)[:, None] + per_hp).ravel()
        self._ordered.add_regions(self.amap.region_subarrays(rpas), rpas)
        added = len(rpas)
        self.stats.preallocated_regions += added
        return added

    # -- helpers -------------------------------------------------------------
    def _nregions(self, size: int) -> int:
        return -(-size // self.region_bytes)

    def _mk_allocation(self, size: int, region_pas: List[int]) -> Allocation:
        """Re-mmap model: scattered regions become one contiguous VA range."""
        va = self._va_next
        self._va_next += len(region_pas) * self.region_bytes
        extents = [
            Extent(i * self.region_bytes, pa, self.region_bytes)
            for i, pa in enumerate(region_pas)
        ]
        alloc = Allocation(va, size, extents, self.name)
        self._allocations[va] = alloc
        self._regions_of[va] = region_pas
        self.stats.live_allocations += 1
        self.stats.regions_in_use += len(region_pas)
        if self.n_channels > 1:
            self._used_per_channel += np.bincount(
                self.amap.region_channels(np.asarray(region_pas, np.int64)),
                minlength=self.n_channels,
            )
        else:
            self._used_per_channel[0] += len(region_pas)
        return alloc

    def _release(self, region_pas: List[int]) -> None:
        if not region_pas:
            return
        pas = np.asarray(region_pas, dtype=np.int64)
        self._ordered.add_regions(self.amap.region_subarrays(pas), pas)
        if self.n_channels > 1:
            self._used_per_channel -= np.bincount(
                self.amap.region_channels(pas), minlength=self.n_channels
            )
        else:
            self._used_per_channel[0] -= len(pas)

    # -- 2) first allocation: worst-fit (paper step (2)) ----------------------
    def pim_alloc(self, size: int) -> Optional[Allocation]:
        need = self._nregions(size)
        if need > self._ordered.total_free():
            self.stats.failed_allocs += 1
            return None
        if self.stripe_channels and self.n_channels > 1:
            return self._pim_alloc_striped(size, need)
        got: List[int] = []
        while len(got) < need:
            sa = self._ordered.worst_fit_subarray()
            if sa is None:  # cannot happen given the total_free gate
                self._release(got)
                self.stats.failed_allocs += 1
                return None
            # drain the worst-fit subarray before moving to the next largest
            while len(got) < need:
                pa = self._ordered.take_from(sa)
                if pa is None:
                    break
                got.append(pa)
        return self._mk_allocation(size, got)

    def _pim_alloc_striped(self, size: int, need: int) -> Optional[Allocation]:
        """Channel-striped worst-fit: region ``k`` comes from the next
        channel in round-robin order (skipping exhausted channels), from
        that channel's emptiest subarray.  Consecutive logical rows then
        live on different channels, so one PUD op's row list partitions
        ~evenly across the per-channel controllers."""
        got: List[int] = []
        while len(got) < need:
            pa = None
            for _ in range(self.n_channels):
                ch = self._next_channel
                self._next_channel = (ch + 1) % self.n_channels
                sa = self._ordered.worst_fit_subarray(channel=ch)
                if sa is None:
                    continue
                pa = self._ordered.take_from(sa)
                if pa is not None:
                    break
            if pa is None:  # cannot happen given the total_free gate
                self._release(got)
                self.stats.failed_allocs += 1
                return None
            got.append(pa)
        return self._mk_allocation(size, got)

    # -- 3) aligned allocation (paper step (3)) -------------------------------
    def pim_alloc_align(self, size: int, hint: Allocation) -> Optional[Allocation]:
        # step 1: hashmap lookup; no match -> allocation fails (paper)
        if hint.va not in self._allocations:
            self.stats.failed_allocs += 1
            return None
        hint_regions = self._regions_of[hint.va]
        need = self._nregions(size)
        if need > self._ordered.total_free():
            self.stats.failed_allocs += 1
            return None
        got: List[int] = []
        # steps 2-4: iterate hint regions, allocate in the same subarray,
        # fall back to worst-fit when that subarray has no free region.
        # One batch decode answers every hint lookup up front; the scalar
        # decode ran once per hint region before.
        hint_sas = self.amap.region_subarrays(
            np.asarray(hint_regions[:need], dtype=np.int64)
        )
        for k in range(need):
            if k < len(hint_regions):
                target_sa = int(hint_sas[k])
                pa = self._ordered.take_from(target_sa)
                if pa is not None:
                    got.append(pa)
                    self.stats.align_hits += 1
                    continue
            self.stats.align_misses += 1
            sa = self._ordered.worst_fit_subarray()
            if sa is None:
                self._release(got)
                self.stats.failed_allocs += 1
                return None
            got.append(self._ordered.take_from(sa))
        # step 5: re-mmap into contiguous VA (modelled by _mk_allocation)
        return self._mk_allocation(size, got)

    # -- beyond-paper: recycling ----------------------------------------------
    def pim_free(self, alloc: Allocation) -> None:
        if alloc.va not in self._allocations:
            raise KeyError(f"{alloc.va:#x} is not a live PUMA allocation")
        region_pas = self._regions_of.pop(alloc.va)
        del self._allocations[alloc.va]
        self._release(region_pas)
        self.stats.live_allocations -= 1
        self.stats.regions_in_use -= len(region_pas)

    # introspection used by tests / benchmarks
    def lookup(self, va: int) -> Optional[Allocation]:
        return self._allocations.get(va)

    def free_regions(self) -> int:
        return self._ordered.total_free()

    def free_counts(self) -> Dict[int, int]:
        return self._ordered.free_counts()

    def channel_report(self) -> Dict[str, object]:
        """Per-channel pool state — the placement-balance figure of merit.

        ``used_balance`` is mean/max of per-channel in-use region counts:
        1.0 means live allocations are perfectly striped across channels,
        1/C means everything sits on one channel (no PUD parallelism).
        """
        used = self._used_per_channel
        mx = int(used.max()) if used.size else 0
        return {
            "channels": self.n_channels,
            "free_regions": self._ordered.channel_free(),
            "used_regions": used.tolist(),
            "used_balance": float(used.mean() / mx) if mx > 0 else 1.0,
        }

    # uniform interface with the baseline allocators
    def alloc(self, size: int) -> Allocation:
        a = self.pim_alloc(size)
        if a is None:
            raise MemoryError("PUMA pool exhausted")
        return a
