"""PUMA: subarray-aware lazy allocation for Processing-Using-Memory (paper §2).

Faithful functional reproduction of the kernel module:

* ``pim_preallocate(n)`` — reserve ``n`` huge pages into the PUD pool; split
  each into rank-row-sized *memory regions*; index every region by its
  global subarray ID using the DRAM interleave decode (:mod:`repro.core.dram`).
* ``pim_alloc(size)`` — worst-fit over the *ordered array* of per-subarray
  free-region counts (paper: a buddy-allocator-style ordered array [146]):
  take regions from the subarray with the most free regions, spilling to the
  next-largest until satisfied.  The returned object is virtually contiguous
  (the kernel re-mmaps scattered regions; here the Allocation's extents model
  exactly that mapping).
* ``pim_alloc_align(size, hint)`` — walk the hint allocation's regions and
  place region *k* of the new allocation in the *same subarray* as region
  *k* of the hint, falling back to worst-fit when that subarray is full
  (paper §2 "Aligned Allocation", steps 1-5).
* an *allocation hashmap* keyed by virtual address tracks live allocations
  so future ``pim_alloc_align`` calls can find their hint.

``pim_free`` is added beyond the paper so that long-running property tests
and the serving integration can recycle the pool.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import TYPE_CHECKING, Dict, List, Optional

import numpy as np

from repro.core.allocators import (
    HUGE_PAGE,
    PAGE,
    Allocation,
    Extent,
    HugePageModel,
    PhysicalMemory,
    PosixMemalignModel,
)
from repro.core.dram import AddressMap
from repro.robustness.errors import (
    BasePageExhausted,
    DoubleFree,
    HugePageExhausted,
    PoolExhausted,
)

from repro.robustness.faults import injected_alloc_miss

if TYPE_CHECKING:
    from repro.robustness.faults import FaultInjector
    from repro.robustness.journal import Journal

__all__ = ["PumaStats", "PumaAllocator", "FallbackStats", "RobustAllocator"]


@dataclasses.dataclass
class PumaStats:
    preallocated_regions: int = 0
    live_allocations: int = 0
    regions_in_use: int = 0
    align_hits: int = 0      # regions placed in the hinted subarray
    align_misses: int = 0    # worst-fit fallbacks during pim_alloc_align
    failed_allocs: int = 0
    injected_misses: int = 0      # transient misses forced by the injector
    quarantined_regions: int = 0  # regions pulled for blacklisted subarrays
    remapped_regions: int = 0     # live regions migrated off dead subarrays


class _OrderedArray:
    """Per-subarray free-region bookkeeping with worst-fit selection.

    The paper uses "an ordered array ... similar to the Linux buddy
    allocator, where each entry represents the number of memory regions in a
    single subarray".  We keep (a) a free-list per subarray and (b) a lazy
    max-heap over (count, subarray) for O(log S) worst-fit.

    With ``channels > 1`` the same bookkeeping is additionally sliced per
    channel (a subarray's channel is ``sa % channels`` — the global ID is
    channel-innermost): one lazy max-heap and one running total per channel,
    so :meth:`worst_fit_subarray` can answer "emptiest subarray *of channel
    c*" in O(log S) for the channel-striping allocation path.
    """

    def __init__(self, channels: int = 1):
        self.channels = channels
        self.free: Dict[int, List[int]] = {}   # subarray -> region PAs (LIFO)
        self._heap: List[tuple] = []           # (-count, subarray), lazy
        self._heap_ch: List[List[tuple]] = [[] for _ in range(channels)]
        self._total = 0                        # running free-region count
        self._total_ch = [0] * channels

    def _push(self, subarray: int) -> None:
        entry = (-len(self.free.get(subarray, ())), subarray)
        heapq.heappush(self._heap, entry)
        if self.channels > 1:
            heapq.heappush(self._heap_ch[subarray % self.channels], entry)

    def add_region(self, subarray: int, pa: int) -> None:
        lst = self.free.setdefault(subarray, [])
        lst.append(pa)
        self._push(subarray)
        self._total += 1
        self._total_ch[subarray % self.channels] += 1

    def add_regions(self, subarrays: np.ndarray, pas: np.ndarray) -> None:
        """Bulk insert: group by subarray, extend each free list once, and
        push ONE heap entry per touched subarray instead of one per region."""
        if len(pas) == 0:
            return
        order = np.argsort(subarrays, kind="stable")
        sas = np.asarray(subarrays)[order]
        ps = np.asarray(pas)[order]
        starts = np.flatnonzero(np.r_[True, sas[1:] != sas[:-1]])
        stops = np.r_[starts[1:], len(sas)]
        for start, stop in zip(starts.tolist(), stops.tolist()):
            sa = int(sas[start])
            lst = self.free.setdefault(sa, [])
            lst.extend(ps[start:stop].tolist())
            self._push(sa)
        self._total += len(ps)
        if self.channels > 1:
            counts = np.bincount(
                sas % self.channels, minlength=self.channels
            )
            for c in range(self.channels):
                self._total_ch[c] += int(counts[c])
        else:
            self._total_ch[0] += len(ps)

    def take_from(self, subarray: int) -> Optional[int]:
        lst = self.free.get(subarray)
        if not lst:
            return None
        pa = lst.pop()
        self._push(subarray)
        self._total -= 1
        self._total_ch[subarray % self.channels] -= 1
        return pa

    def take_specific(self, subarray: int, pa: int) -> bool:
        """Remove one *specific* region PA from a subarray's free list —
        the forced-placement primitive journal replay uses to reproduce the
        original allocator's decisions exactly (worst-fit tie-breaks are
        irrelevant when every placement is replayed from the log)."""
        lst = self.free.get(subarray)
        if not lst or pa not in lst:
            return False
        lst.remove(pa)
        self._push(subarray)
        self._total -= 1
        self._total_ch[subarray % self.channels] -= 1
        return True

    def worst_fit_subarray(self, channel: Optional[int] = None) -> Optional[int]:
        """Subarray with the largest number of free regions (lazy heap);
        restricted to one channel's subarrays when ``channel`` is given."""
        # channels == 1: the global view IS channel 0's view (and _push
        # skips the per-channel heaps to keep preallocation cheap)
        if channel is None or self.channels == 1:
            heap = self._heap
        else:
            heap = self._heap_ch[channel]
        while heap:
            neg, sa = heap[0]
            if len(self.free.get(sa, ())) == -neg and -neg > 0:
                return sa
            heapq.heappop(heap)  # stale entry
        return None

    def drain(self, subarray: int) -> List[int]:
        """Remove and return every free region of ``subarray`` (blacklist
        quarantine).  Heap entries invalidate lazily via a 0-count push."""
        lst = self.free.pop(subarray, [])
        if lst:
            self._total -= len(lst)
            self._total_ch[subarray % self.channels] -= len(lst)
            self._push(subarray)
        return lst

    def total_free(self, channel: Optional[int] = None) -> int:
        return self._total if channel is None else self._total_ch[channel]

    def channel_free(self) -> List[int]:
        return list(self._total_ch)

    def free_counts(self) -> Dict[int, int]:
        return {sa: len(v) for sa, v in self.free.items() if v}


class PumaAllocator:
    name = "puma"

    def __init__(
        self,
        mem: PhysicalMemory,
        amap: Optional[AddressMap] = None,
        *,
        stripe_channels: bool = False,
        injector: Optional["FaultInjector"] = None,
        journal: Optional["Journal"] = None,
    ):
        self.mem = mem
        self.amap = amap or mem.amap
        self.region_bytes = self.amap.region_bytes
        self.n_channels = self.amap.geo.channels
        #: stripe first allocations round-robin across channels (worst-fit
        #: *within* each channel) so consecutive logical rows land on
        #: different channels and the channel-parallel PUD executor scales.
        #: Off by default — and a no-op at channels=1 — so the paper's
        #: single-channel placement is untouched.
        self.stripe_channels = stripe_channels
        self._next_channel = 0
        self._ordered = _OrderedArray(self.n_channels)
        self._used_per_channel = np.zeros(self.n_channels, dtype=np.int64)
        self._allocations: Dict[int, Allocation] = {}  # the allocation hashmap
        self._regions_of: Dict[int, List[int]] = {}    # va -> region PAs
        self._va_next = 0x7000_0000_0000
        self.stats = PumaStats()
        #: fault injector (transient alloc misses + permanent-fault
        #: blacklist source); None = fault-free.
        self.injector = injector
        #: subarrays quarantined after permanent faults; their regions are
        #: never handed out again.
        self._blacklisted: set = set()
        self._quarantined: List[int] = []   # region PAs pulled from the pool
        if injector is not None:
            for sa in sorted(injector.blacklist):
                self._blacklisted.add(sa)
        #: crash-consistency journal (``repro.robustness.journal``): every
        #: state-changing operation appends its *outcome* (actual placements)
        #: so replay is forced and bit-exact; None = not journaled.
        self.journal = journal

    # -- 1) pre-allocation (paper step (1)) ---------------------------------
    def pim_preallocate(self, n_huge_pages: int) -> int:
        """Populate the PUD pool; returns the number of regions indexed.

        Every huge page's regions are decoded in one numpy batch (huge pages
        are region-aligned, so the region set is a plain arange) and inserted
        grouped-by-subarray — no per-region Python calls.
        """
        hps = self.mem.take_huge(n_huge_pages)
        if not hps:
            return 0
        if self.journal is not None:
            self.journal.append("prealloc", hps=list(hps))
        rb = self.region_bytes
        per_hp = np.arange(HUGE_PAGE // rb, dtype=np.int64) * rb
        rpas = (np.asarray(hps, dtype=np.int64)[:, None] + per_hp).ravel()
        sas = self.amap.region_subarrays(rpas)
        self.stats.preallocated_regions += len(rpas)
        if self._blacklisted:
            # regions landing in dead subarrays go straight to quarantine
            bl = np.fromiter(self._blacklisted, dtype=np.int64)
            bad = np.isin(sas, bl)
            if bad.any():
                self._quarantined.extend(rpas[bad].tolist())
                self.stats.quarantined_regions += int(bad.sum())
                rpas, sas = rpas[~bad], sas[~bad]
        self._ordered.add_regions(sas, rpas)
        return len(rpas)

    # -- helpers -------------------------------------------------------------
    def _nregions(self, size: int) -> int:
        return -(-size // self.region_bytes)

    def _mk_allocation(self, size: int, region_pas: List[int]) -> Allocation:
        """Re-mmap model: scattered regions become one contiguous VA range."""
        va = self._va_next
        self._va_next += len(region_pas) * self.region_bytes
        extents = [
            Extent(i * self.region_bytes, pa, self.region_bytes)
            for i, pa in enumerate(region_pas)
        ]
        alloc = Allocation(va, size, extents, self.name)
        self._allocations[va] = alloc
        self._regions_of[va] = region_pas
        if self.journal is not None:
            self.journal.append(
                "alloc", va=va, size=size, regions=list(region_pas)
            )
        self.stats.live_allocations += 1
        self.stats.regions_in_use += len(region_pas)
        if self.n_channels > 1:
            self._used_per_channel += np.bincount(
                self.amap.region_channels(np.asarray(region_pas, np.int64)),
                minlength=self.n_channels,
            )
        else:
            self._used_per_channel[0] += len(region_pas)
        return alloc

    def _release(self, region_pas: List[int]) -> None:
        if not region_pas:
            return
        pas = np.asarray(region_pas, dtype=np.int64)
        # regions leave the in-use set either way (freed or quarantined)
        if self.n_channels > 1:
            self._used_per_channel -= np.bincount(
                self.amap.region_channels(pas), minlength=self.n_channels
            )
        else:
            self._used_per_channel[0] -= len(pas)
        sas = self.amap.region_subarrays(pas)
        if self._blacklisted:
            # freed regions of dead subarrays are quarantined, not recycled
            bl = np.fromiter(self._blacklisted, dtype=np.int64)
            bad = np.isin(sas, bl)
            if bad.any():
                self._quarantined.extend(pas[bad].tolist())
                self.stats.quarantined_regions += int(bad.sum())
                pas, sas = pas[~bad], sas[~bad]
                if pas.size == 0:
                    return
        self._ordered.add_regions(sas, pas)

    def _injected_miss(self) -> bool:
        """Transient fragmented-arena miss forced by the fault injector
        (shared hook — see :func:`repro.robustness.faults.injected_alloc_miss`)."""
        return injected_alloc_miss(self.injector, self.stats, "failed_allocs")

    # -- 2) first allocation: worst-fit (paper step (2)) ----------------------
    def pim_alloc(self, size: int) -> Optional[Allocation]:
        self.sync_blacklist()
        if self._injected_miss():
            return None
        need = self._nregions(size)
        if need > self._ordered.total_free():
            self.stats.failed_allocs += 1
            return None
        if self.stripe_channels and self.n_channels > 1:
            return self._pim_alloc_striped(size, need)
        got: List[int] = []
        while len(got) < need:
            sa = self._ordered.worst_fit_subarray()
            if sa is None:  # cannot happen given the total_free gate
                self._release(got)
                self.stats.failed_allocs += 1
                return None
            # drain the worst-fit subarray before moving to the next largest
            while len(got) < need:
                pa = self._ordered.take_from(sa)
                if pa is None:
                    break
                got.append(pa)
        return self._mk_allocation(size, got)

    def _pim_alloc_striped(self, size: int, need: int) -> Optional[Allocation]:
        """Channel-striped worst-fit: region ``k`` comes from the next
        channel in round-robin order (skipping exhausted channels), from
        that channel's emptiest subarray.  Consecutive logical rows then
        live on different channels, so one PUD op's row list partitions
        ~evenly across the per-channel controllers."""
        got: List[int] = []
        while len(got) < need:
            pa = None
            for _ in range(self.n_channels):
                ch = self._next_channel
                self._next_channel = (ch + 1) % self.n_channels
                sa = self._ordered.worst_fit_subarray(channel=ch)
                if sa is None:
                    continue
                pa = self._ordered.take_from(sa)
                if pa is not None:
                    break
            if pa is None:  # cannot happen given the total_free gate
                self._release(got)
                self.stats.failed_allocs += 1
                return None
            got.append(pa)
        return self._mk_allocation(size, got)

    # -- 3) aligned allocation (paper step (3)) -------------------------------
    def pim_alloc_align(self, size: int, hint: Allocation) -> Optional[Allocation]:
        # step 1: hashmap lookup; no match -> allocation fails (paper)
        if hint.va not in self._allocations:
            self.stats.failed_allocs += 1
            return None
        self.sync_blacklist()
        if self._injected_miss():
            return None
        hint_regions = self._regions_of[hint.va]
        need = self._nregions(size)
        if need > self._ordered.total_free():
            self.stats.failed_allocs += 1
            return None
        got: List[int] = []
        # steps 2-4: iterate hint regions, allocate in the same subarray,
        # fall back to worst-fit when that subarray has no free region.
        # One batch decode answers every hint lookup up front; the scalar
        # decode ran once per hint region before.
        hint_sas = self.amap.region_subarrays(
            np.asarray(hint_regions[:need], dtype=np.int64)
        )
        for k in range(need):
            if k < len(hint_regions):
                target_sa = int(hint_sas[k])
                pa = self._ordered.take_from(target_sa)
                if pa is not None:
                    got.append(pa)
                    self.stats.align_hits += 1
                    continue
            self.stats.align_misses += 1
            sa = self._ordered.worst_fit_subarray()
            if sa is None:
                self._release(got)
                self.stats.failed_allocs += 1
                return None
            got.append(self._ordered.take_from(sa))
        # step 5: re-mmap into contiguous VA (modelled by _mk_allocation)
        return self._mk_allocation(size, got)

    # -- beyond-paper: recycling ----------------------------------------------
    def pim_free(self, alloc: Allocation) -> None:
        if alloc.va not in self._allocations:
            raise DoubleFree(
                f"{alloc.va:#x} is not a live PUMA allocation", va=alloc.va
            )
        region_pas = self._regions_of.pop(alloc.va)
        del self._allocations[alloc.va]
        if self.journal is not None:
            self.journal.append("free", va=alloc.va)
        self._release(region_pas)
        self.stats.live_allocations -= 1
        self.stats.regions_in_use -= len(region_pas)

    # -- robustness: permanent-fault blacklisting + row remap -----------------
    def sync_blacklist(self) -> int:
        """Pull newly blacklisted subarrays from the fault injector (permanent
        RowClone failures observed by the PUD executor) and quarantine/remap
        them.  Returns the number of subarrays newly blacklisted."""
        if self.injector is None:
            return 0
        fresh = self.injector.new_permanent_faults(self._blacklisted)
        for sa in sorted(fresh):
            self.blacklist_subarray(sa)
        return len(fresh)

    def blacklist_subarray(self, sa: int, phys: Optional[np.ndarray] = None) -> int:
        """Handle a permanent subarray failure: quarantine its free regions
        and *remap* every live allocation's regions out of it (the kernel's
        row-remap path; the migration itself is a RowClone copy per row —
        pass ``phys`` to actually move the bytes on the modeled memory).

        Returns the number of live regions remapped.  Raises
        :class:`PoolExhausted` when the pool has no spare region to remap
        into (the row's data would be lost on real hardware; callers should
        treat the allocation as failed).
        """
        self._blacklisted.add(sa)
        drained = self._ordered.drain(sa)
        if drained:
            self._quarantined.extend(drained)
            self.stats.quarantined_regions += len(drained)
        remapped = 0
        remap_log: List[List[int]] = []   # [va, k, old_pa, new_pa] per move
        rb = self.region_bytes
        for va, regions in self._regions_of.items():
            if not regions:
                continue
            sas = self.amap.region_subarrays(np.asarray(regions, np.int64))
            hits = np.flatnonzero(sas == sa)
            if hits.size == 0:
                continue
            for k in hits.tolist():
                tgt = self._ordered.worst_fit_subarray()
                new_pa = self._ordered.take_from(tgt) if tgt is not None else None
                if new_pa is None:
                    raise PoolExhausted(
                        "no spare region to remap faulty subarray into",
                        subarray=sa, va=va,
                    )
                old_pa = regions[k]
                if phys is not None:
                    phys[new_pa:new_pa + rb] = phys[old_pa:old_pa + rb]
                self._quarantined.append(old_pa)
                self.stats.quarantined_regions += 1
                regions[k] = new_pa
                remap_log.append([va, k, old_pa, new_pa])
                remapped += 1
                if self.n_channels > 1:
                    self._used_per_channel[
                        int(self.amap.channel_of_subarray(sa))] -= 1
                    self._used_per_channel[
                        int(self.amap.channel_of_subarray(int(tgt)))] += 1
            # rebuild the allocation's extent list in place (same VA, same
            # hashmap identity — aligned-allocation hints keep working)
            alloc = self._allocations[va]
            alloc.extents = [
                Extent(i * rb, pa, rb) for i, pa in enumerate(regions)
            ]
            alloc.__post_init__()
        self.stats.remapped_regions += remapped
        if self.journal is not None:
            self.journal.append(
                "blacklist", sa=sa, drained=list(drained), remaps=remap_log
            )
        return remapped

    @property
    def blacklisted_subarrays(self) -> List[int]:
        return sorted(self._blacklisted)

    def quarantined_regions(self) -> int:
        return len(self._quarantined)

    # introspection used by tests / benchmarks
    def lookup(self, va: int) -> Optional[Allocation]:
        return self._allocations.get(va)

    def free_regions(self) -> int:
        return self._ordered.total_free()

    def free_counts(self) -> Dict[int, int]:
        return self._ordered.free_counts()

    def fragmentation(self) -> float:
        """1 - (largest per-subarray free count / total free) — the allocator
        mirror of :meth:`repro.core.arena.TilePool.fragmentation`.

        Regions inside one subarray are interchangeable for PUD placement, so
        the "largest free run" at this layer is the biggest block of
        co-locatable free regions: 0.0 means all free capacity sits in one
        subarray (any future aligned pair co-locates), values near 1.0 mean
        the free capacity is spread one region per subarray and
        ``pim_alloc_align`` is doomed to worst-fit misses — the churn-decay
        signal the long-horizon benchmark tracks.
        """
        total = self._ordered.total_free()
        if total == 0:
            return 0.0
        best = max((len(v) for v in self._ordered.free.values()), default=0)
        return 1.0 - best / total

    def channel_report(self) -> Dict[str, object]:
        """Per-channel pool state — the placement-balance figure of merit.

        ``used_balance`` is mean/max of per-channel in-use region counts:
        1.0 means live allocations are perfectly striped across channels,
        1/C means everything sits on one channel (no PUD parallelism).
        """
        used = self._used_per_channel
        mx = int(used.max()) if used.size else 0
        return {
            "channels": self.n_channels,
            "free_regions": self._ordered.channel_free(),
            "used_regions": used.tolist(),
            "used_balance": float(used.mean() / mx) if mx > 0 else 1.0,
            "fragmentation": self.fragmentation(),
        }

    # uniform interface with the baseline allocators
    def alloc(self, size: int) -> Allocation:
        a = self.pim_alloc(size)
        if a is None:
            raise PoolExhausted(
                "PUMA pool exhausted", wanted=self._nregions(size),
                free=self._ordered.total_free(),
            )
        return a


# ---------------------------------------------------------------------------
# Recovery layer: bounded retry-with-backoff fallback chain (ISSUE 7).
# Mirrors the kernel allocator's fallback order: PUD pool (PUMA) -> fresh
# huge pages -> scattered base pages.  Each tier degrades placement quality
# (PUD-executable -> row-aligned-but-opportunistic -> 0% PUD) but never
# fails the caller until base pages are gone too.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FallbackStats:
    puma: int = 0            # allocations served by the PUD pool
    huge: int = 0            # ... by fresh huge pages (degraded tier 2)
    base: int = 0            # ... by scattered base pages (degraded tier 3)
    retries: int = 0         # failed attempts that were retried
    refills: int = 0         # pim_preallocate refills between retries
    failures: int = 0        # requests that exhausted every tier
    backoff_ns: float = 0.0  # simulated backoff time spent waiting

    @property
    def served(self) -> int:
        return self.puma + self.huge + self.base

    def fallback_fraction(self) -> float:
        """Fraction of served allocations that fell off the PUMA tier."""
        return (self.huge + self.base) / self.served if self.served else 0.0


class RobustAllocator:
    """Hardened allocation front-end over a :class:`PumaAllocator`.

    ``alloc`` walks the chain PUMA -> huge-page -> base-page with bounded
    per-tier retries and exponential (simulated) backoff:

    1. **PUMA tier** — ``pim_alloc``/``pim_alloc_align``; a miss triggers a
       pool refill (``pim_preallocate``) when the pool is genuinely short,
       then a bounded retry (which also absorbs injector-transient misses).
    2. **huge-page tier** — per-request fresh huge pages (row-aligned but
       only opportunistically co-located, the paper's strongest baseline);
       injector denials are retried up to ``max_retries``.
    3. **base-page tier** — scattered 4 KB pages (0 % PUD-executable).

    Raises :class:`PoolExhausted` only when every tier is dry.  ``free``
    routes by the allocation's ``allocator`` tag so callers can churn
    without tracking which tier served them.
    """

    name = "puma-robust"

    def __init__(
        self,
        puma: PumaAllocator,
        *,
        max_retries: int = 3,
        backoff_ns: float = 200.0,
        refill_huge_pages: int = 8,
    ):
        self.puma = puma
        self.mem = puma.mem
        self.max_retries = max_retries
        self.backoff_ns = backoff_ns
        self.refill_huge_pages = refill_huge_pages
        self._huge = HugePageModel(puma.mem, mode="mmap")
        self._base = PosixMemalignModel(puma.mem)
        self._tier_of: Dict[int, str] = {}   # va -> serving tier
        self.stats = FallbackStats()

    def _backoff(self, attempt: int) -> None:
        self.stats.retries += 1
        self.stats.backoff_ns += self.backoff_ns * (2 ** attempt)

    # -- tier 1: PUMA ---------------------------------------------------------
    def _try_puma(self, size: int, hint: Optional[Allocation]) -> Optional[Allocation]:
        for attempt in range(self.max_retries + 1):
            if hint is not None:
                a = self.puma.pim_alloc_align(size, hint)
                if a is None and self.puma.lookup(hint.va) is None:
                    # dead hint: aligned allocation can never succeed (paper);
                    # fall through to plain worst-fit instead of retrying.
                    hint = None
                    a = self.puma.pim_alloc(size)
            else:
                a = self.puma.pim_alloc(size)
            if a is not None:
                return a
            if attempt == self.max_retries:
                break
            self._backoff(attempt)
            need = self.puma._nregions(size)
            if need > self.puma.free_regions():
                # genuinely short: grow the PUD pool like the kernel module
                try:
                    self.puma.pim_preallocate(self.refill_huge_pages)
                    self.stats.refills += 1
                except HugePageExhausted as e:
                    if not e.injected:
                        break   # reservation is truly dry: go to tier 2
        return None

    # -- tier 2/3: degraded --------------------------------------------------
    def _try_huge(self, size: int) -> Optional[Allocation]:
        for attempt in range(self.max_retries + 1):
            try:
                return self._huge.alloc(size)
            except HugePageExhausted as e:
                if not e.injected:
                    return None
                if attempt < self.max_retries:
                    self._backoff(attempt)
        return None

    def alloc(self, size: int, hint: Optional[Allocation] = None) -> Allocation:
        a = self._try_puma(size, hint)
        if a is not None:
            self.stats.puma += 1
            self._tier_of[a.va] = "puma"
            return a
        a = self._try_huge(size)
        if a is not None:
            self.stats.huge += 1
            self._tier_of[a.va] = "huge"
            return a
        try:
            a = self._base.alloc(size)
        except BasePageExhausted:
            self.stats.failures += 1
            raise PoolExhausted(
                "allocation failed in every tier (puma, huge, base)",
                size=size,
            )
        self.stats.base += 1
        self._tier_of[a.va] = "base"
        return a

    def free(self, alloc: Allocation) -> None:
        tier = self._tier_of.pop(alloc.va, None)
        if tier is None:
            raise DoubleFree(
                f"{alloc.va:#x} was not served by this allocator", va=alloc.va
            )
        if tier == "puma":
            self.puma.pim_free(alloc)
        elif tier == "huge":
            # mmap-mode huge allocations own whole (coalesced) huge pages
            self.mem.release_huge(
                [e.pa + off
                 for e in alloc.extents
                 for off in range(0, e.nbytes, HUGE_PAGE)]
            )
        else:  # base pages
            self.mem.release_pages(
                [e.pa + off
                 for e in alloc.extents
                 for off in range(0, e.nbytes, PAGE)]
            )

    def tier_of(self, alloc: Allocation) -> Optional[str]:
        return self._tier_of.get(alloc.va)
