"""Logical-axis sharding: mesh registry + MaxText-style axis rules.

Model code names *logical* axes ("batch", "embed", "kv_seq", ...); this
module maps them onto whatever mesh is active.  Everything degrades to a
no-op when no mesh is set — ``constraint`` returns its input unchanged —
so single-host tests and the CPU container run the exact same model code
that the 256/512-chip dry-run compiles.

Key pieces:

* ``set_mesh``/``use_mesh``/``get_mesh`` — a process-global active mesh
  (``use_mesh`` is the scoped context-manager form).
* ``PARAM_RULES``/``ACT_RULES`` — mutable logical->mesh-axis dictionaries
  (parameter axes vs activation axes).  ``override_rules`` /
  ``override_param_rules`` scope an update and restore on exit.
* ``logical_spec(*names)`` — a ``PartitionSpec`` for the active mesh, with
  the "pod" data-parallel axis automatically prepended to the batch entry
  on multi-pod meshes.
* ``filter_spec(spec, shape, mesh)`` — divisibility filter: any entry whose
  mesh-axis product does not evenly divide the corresponding dim is dropped
  to ``None`` (GSPMD would otherwise reject the sharding); short specs are
  padded with ``None`` to the array rank.
* ``shardings_for`` / ``axes_to_shardings`` — pytree helpers producing
  ``NamedSharding`` trees for parameter specs and logical-axis-name trees.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "PARAM_RULES",
    "ACT_RULES",
    "shard_map_compat",
    "get_mesh",
    "set_mesh",
    "use_mesh",
    "logical_spec",
    "filter_spec",
    "constraint",
    "shardings_for",
    "axes_to_shardings",
    "override_rules",
    "override_param_rules",
]

try:  # jax >= 0.6
    from jax import shard_map as shard_map_compat
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map_compat(f, **kw):
        """``jax.shard_map`` across jax versions (older jax spells the
        ``check_vma`` kwarg ``check_rep`` and lives under experimental)."""
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        return _shard_map_old(f, **kw)


#: logical parameter axis -> mesh axis (or tuple of axes, or None=replicated).
#: "embed" carries FSDP ("data"); the tensor-parallel dims ride "model".
PARAM_RULES: Dict[str, Any] = {
    "embed": "data",
    "embed_tp": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "experts": None,
    "state": None,
    "conv": None,
    "layers": None,
}

#: logical activation axis -> mesh axis.
ACT_RULES: Dict[str, Any] = {
    "batch": "data",
    "seq": None,
    "seq_res": None,
    "kv_seq": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
}

_ACTIVE_MESH = None


def get_mesh():
    """The active mesh, or None (=> every helper becomes a passthrough)."""
    return _ACTIVE_MESH


def set_mesh(mesh) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


@contextlib.contextmanager
def use_mesh(mesh):
    """Scoped ``set_mesh``: restores the previous mesh on exit."""
    global _ACTIVE_MESH
    prev, _ACTIVE_MESH = _ACTIVE_MESH, mesh
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev


def _mesh_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _rule_entry(name: Optional[str], mesh, rules: Dict[str, Any]) -> Any:
    """Resolve one logical axis name to a spec entry under ``mesh``."""
    if name is None:
        return None
    rule = rules.get(name)
    if rule is None:
        return None
    axes: Tuple[str, ...] = rule if isinstance(rule, tuple) else (rule,)
    if (
        name == "batch"
        and mesh is not None
        and "pod" in getattr(mesh, "axis_names", ())
        and "pod" not in axes
    ):
        # multi-pod meshes carry pure data parallelism on the leading "pod"
        # axis; batch entries absorb it transparently.
        axes = ("pod",) + axes
    if mesh is not None:
        axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def logical_spec(*names: Optional[str]) -> P:
    """PartitionSpec for logical activation axes under the active mesh."""
    mesh = get_mesh()
    return P(*[_rule_entry(n, mesh, ACT_RULES) for n in names])


def filter_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop spec entries that do not evenly divide the array shape."""
    sizes = _mesh_sizes(mesh)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if all(a in sizes for a in axes):
            prod = 1
            for a in axes:
                prod *= sizes[a]
            if prod and dim % prod == 0:
                out.append(entry)
                continue
        out.append(None)
    return P(*out)


def constraint(x, *names: Optional[str]):
    """``with_sharding_constraint`` by logical axis names; identity when no
    mesh is active (the single-host / unit-test path)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = filter_spec(logical_spec(*names), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _is_spec(x) -> bool:
    return isinstance(x, P)


def shardings_for(specs: Any, abs_tree: Any, mesh) -> Any:
    """PartitionSpec tree (e.g. from ``spec_tree``) -> NamedSharding tree,
    divisibility-filtered against the matching abstract arrays."""
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, filter_spec(s, a.shape, mesh)),
        specs,
        abs_tree,
        is_leaf=_is_spec,
    )


def _is_axes_leaf(x) -> bool:
    # a leaf is a per-dim tuple of logical axis names, e.g. (None, "batch",
    # "kv_seq", None, None) — or () for scalar leaves.  Containers (dicts,
    # NamedTuples of such tuples) keep getting traversed.
    return x is None or (
        type(x) is tuple and all(e is None or isinstance(e, str) for e in x)
    )


def axes_to_shardings(axes: Any, abs_tree: Any, mesh) -> Any:
    """Tree of logical-axis-name tuples -> tree of NamedSharding."""
    abs_leaves, treedef = jax.tree.flatten(abs_tree)
    axes_leaves = jax.tree.flatten(axes, is_leaf=_is_axes_leaf)[0]
    assert len(axes_leaves) == len(abs_leaves), (len(axes_leaves), len(abs_leaves))
    out = []
    for ax, a in zip(axes_leaves, abs_leaves):
        names = () if ax is None else ax
        spec = P(*[_rule_entry(n, mesh, ACT_RULES) for n in names])
        out.append(NamedSharding(mesh, filter_spec(spec, a.shape, mesh)))
    return jax.tree.unflatten(treedef, out)


@contextlib.contextmanager
def override_rules(**updates):
    """Scoped ACT_RULES update (e.g. a shape-specific kv_seq placement)."""
    saved = dict(ACT_RULES)
    ACT_RULES.update(updates)
    try:
        yield
    finally:
        ACT_RULES.clear()
        ACT_RULES.update(saved)


@contextlib.contextmanager
def override_param_rules(**updates):
    """Scoped PARAM_RULES update (e.g. inference flips embed -> None)."""
    saved = dict(PARAM_RULES)
    PARAM_RULES.update(updates)
    try:
        yield
    finally:
        PARAM_RULES.clear()
        PARAM_RULES.update(saved)
