"""Distribution plumbing: logical-axis sharding rules and mesh registry."""
