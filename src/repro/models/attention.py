"""Multi-head attention (MHA/GQA/MQA) with selectable inner implementation.

``impl``:
  * "naive"   — materializes the (S, S) score matrix (the un-fused XLA
                baseline; what you get without a flash kernel),
  * "chunked" — XLA-visible online-softmax over KV blocks via lax.scan
                (flash-style memory behaviour, analyzable by cost_analysis),
  * "pallas"  — the repro.kernels.flash_attention TPU kernel (used on real
                hardware and in kernel tests; opaque to HLO cost analysis).

Decode mode consumes/produces an explicit KV cache
``(k, v): (B, S_max, KV, hd)`` plus the current length, updating in place
with dynamic_update_slice — the dense-cache path used by the dry-run; the
serving engine swaps in the PUMA paged pool on-line.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constraint
from repro.models.params import ParamDef
from repro.models.rope import apply_rope

Cache = Tuple[jax.Array, jax.Array]  # (k, v) each (B, S_max, KV, hd)

NEG_INF = -1e30


def attn_defs(cfg: ModelConfig, d_model: Optional[int] = None) -> Dict:
    d = d_model or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "embed")),
    }


def _repeat_kv(k: jax.Array, group: int) -> jax.Array:
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


def _naive_attention(q, k, v, *, causal, kv_len, scale, q_offset=0):
    """q (B,Sq,H,hd), k/v (B,Sk,H,hd) — full score matrix."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    kpos = jnp.arange(Sk)[None, None, None, :]
    mask = kpos < kv_len
    if causal:
        qpos = (q_offset + jnp.arange(Sq))[None, None, :, None]
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _attention_with_lse(q, k, v, *, kv_len, kv_offset, scale, q_pos):
    """Partial attention over one KV segment, returning (out_f32, lse).

    q (B,Sq,H,hd); k/v (B,Sk,KV,hd).  GQA-native grouped einsums: KV is
    never repeated ``group`` times and never materialized in f32 — the dots
    accumulate in f32 via preferred_element_type (quantized fp8 pages are
    widened to the compute dtype elementwise, which fuses into the dot).
    Segment tokens occupy absolute positions [kv_offset, kv_offset+kv_len);
    causal masking uses absolute query positions ``q_pos`` (B, Sq).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    Sk = k.shape[1]
    cd = q.dtype  # compute dtype (bf16 in production)
    # barrier: keeps the (quantized) page widening *inside* the layer loop —
    # XLA otherwise hoists the convert and materializes the whole stacked
    # cache in compute dtype (a 2x cache-sized temp).
    k, v = jax.lax.optimization_barrier((k, v))
    qg = q.reshape(B, Sq, KV, group, hd)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k.astype(cd),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (B,KV,g,Sq,Sk)
    kpos = kv_offset + jnp.arange(Sk)
    mask = (jnp.arange(Sk)[None, None, None, None, :] < kv_len) & (
        kpos[None, None, None, None, :] <= q_pos[:, None, None, :, None]
    )
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(-1)                                          # (B,KV,g,Sq)
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(-1)
    out = jnp.einsum(
        "bkgqs,bskd->bkgqd", p.astype(cd), v.astype(cd),
        preferred_element_type=jnp.float32,
    )
    out = out / jnp.where(l == 0.0, 1.0, l)[..., None]
    lse = jnp.where(l == 0.0, NEG_INF, m + jnp.log(jnp.where(l == 0.0, 1.0, l)))
    # -> (B, Sq, H, hd), (B, Sq, H)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    lse = lse.transpose(0, 3, 1, 2).reshape(B, Sq, H)
    return out, lse


def merge_segments(parts):
    """Exactly combine [(out_normalized, lse), ...] partial attentions."""
    m = parts[0][1]
    for _, lse in parts[1:]:
        m = jnp.maximum(m, lse)
    m = jnp.maximum(m, NEG_INF)  # keep finite when all segments are empty
    num = 0.0
    den = 0.0
    for out, lse in parts:
        w = jnp.exp(lse - m)                                # (B,Sq,H)
        num = num + out * w[..., None]
        den = den + w
    den = jnp.where(den == 0.0, 1.0, den)
    return num / den[..., None]


def _chunked_attention(q, k, v, *, causal, kv_len, scale, q_offset=0, block_k=512):
    """Online-softmax over KV chunks (XLA flash): O(Sq*bk) live memory.

    GQA-native: q is grouped per KV head ("bqkgd,bskd" einsums), so KV is
    never materialized repeated ``group`` times — at 72B-decode scale that's
    the difference between a 268 MB and a 2 GB per-device working set.  The
    chunk body is rematerialized (jax.checkpoint) so the backward pass
    re-derives the (Sq, block_k) score tile instead of saving one per chunk.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    Sk = k.shape[1]
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = k.shape[1] // block_k
    kb = k.reshape(B, nkb, block_k, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nkb, block_k, KV, hd).transpose(1, 0, 2, 3, 4)

    qf = q.reshape(B, Sq, KV, group, hd).astype(jnp.float32)
    qpos = (q_offset + jnp.arange(Sq))[None, None, None, :, None]

    @jax.checkpoint
    def body(carry, blk):
        m, l, acc = carry
        kc, vc, ki = blk
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32)
        ) * scale                                      # (B, KV, g, Sq, bk)
        kpos = (ki * block_k + jnp.arange(block_k))[None, None, None, None, :]
        mask = kpos < kv_len
        if causal:
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        l_new = alpha * l + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, group, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, group, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, group, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nkb))
    )
    out = acc / jnp.where(l[..., None] == 0, 1.0, l[..., None])
    # (B, KV, g, Sq, hd) -> (B, Sq, H, hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def _inner_attention(q, k, v, *, impl, causal, kv_len, scale, q_offset=0):
    group = q.shape[2] // k.shape[2]
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fl

        o = fl.flash_attention(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=causal,
            scale=scale,
        )
        return o.transpose(0, 2, 1, 3)
    if impl == "chunked":
        return _chunked_attention(
            q, k, v, causal=causal, kv_len=kv_len, scale=scale, q_offset=q_offset
        )
    k = _repeat_kv(k, group)
    v = _repeat_kv(v, group)
    return _naive_attention(
        q, k, v, causal=causal, kv_len=kv_len, scale=scale, q_offset=q_offset
    )


def apply_attention(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, S, d)
    positions: jax.Array,               # (B, S) or (B, S, 3)
    *,
    impl: str = "naive",
    causal: bool = True,
    cache: Optional[Cache] = None,
    cache_len: Optional[jax.Array] = None,   # scalar int32: tokens already cached
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
) -> Tuple[jax.Array, Optional[Cache]]:
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    scale = 1.0 / math.sqrt(hd)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cache is not None and S == 1:
        # decode: q is tiny — replicate heads so the attention contractions
        # stay aligned with the kv_seq-sharded cache (split-K pattern: the
        # softmax/PV reductions become small partial-sum all-reduces instead
        # of a full cache re-shard to a heads-sharded layout).
        q = constraint(q, "batch", "seq", None, None)
    else:
        q = constraint(q, "batch", "seq", "heads", None)
    q = apply_rope(cfg, q, positions)

    if kv_override is not None:
        k, v = kv_override
        out = _inner_attention(
            q, k, v, impl=impl, causal=False,
            kv_len=cache_len if cache_len is not None else k.shape[1],
            scale=scale,
        )
        new_cache = cache
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        k = constraint(k, "batch", "seq", "kv_heads", None)
        v = constraint(v, "batch", "seq", "kv_heads", None)
        k = apply_rope(cfg, k, positions)

        if cache is None:
            out = _inner_attention(
                q, k, v, impl=impl, causal=causal, kv_len=S, scale=scale
            )
            new_cache = None
        elif isinstance(cache, dict):
            # Split KV cache: "main" is the big kv_seq-sharded store
            # (READ-ONLY within a decode step — never DUS'd on its sharded
            # dim, which would force a full-cache reshard), "recent" is a
            # small batch-sharded ring the new tokens append to; a separate
            # amortized flush moves recent -> main every R steps.  The two
            # segments merge exactly via logsumexp weights.
            mk, mv = cache["main"]
            rk, rv = cache["recent"]
            len_main, len_rec = cache_len  # (tokens in main, tokens in recent)
            rk = jax.lax.dynamic_update_slice(
                rk, k.astype(rk.dtype), (0, len_rec, 0, 0)
            )
            rv = jax.lax.dynamic_update_slice(
                rv, v.astype(rv.dtype), (0, len_rec, 0, 0)
            )
            q_pos = positions[:, :, 0] if positions.ndim == 3 else positions
            out_m, lse_m = _attention_with_lse(
                q, mk, mv, kv_len=len_main, kv_offset=0, scale=scale,
                q_pos=q_pos,
            )
            out_r, lse_r = _attention_with_lse(
                q, rk, rv, kv_len=len_rec + S, kv_offset=len_main,
                scale=scale, q_pos=q_pos,
            )
            out = merge_segments([(out_m, lse_m), (out_r, lse_r)]).astype(q.dtype)
            # main is read-only: return ONLY the recent ring so a scanned
            # layer stack never double-buffers the big store as scan ys
            new_cache = {"recent": (rk, rv)}
        else:
            ck, cv = cache
            ck = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, cache_len, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, cache_len, 0, 0)
            )
            # Decode (S==1) always takes the score-materializing path: the
            # score tile is (B, H, 1, Sk) — linear, not quadratic — and its
            # softmax/PV contractions partition cleanly over the kv_seq-
            # sharded cache (GSPMD turns them into partial sums), whereas a
            # scan over KV chunks would slice the sharded dim per step.
            decode_impl = "naive" if S == 1 else impl
            out = _inner_attention(
                q, ck, cv,
                impl=decode_impl, causal=causal, kv_len=cache_len + S,
                scale=scale, q_offset=cache_len,
            )
            new_cache = (ck, cv)

    if cache is not None and S == 1:
        out = constraint(out, "batch", "seq", None, None)
    else:
        out = constraint(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return constraint(y, "batch", "seq_res", None), new_cache
