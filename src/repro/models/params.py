"""Declarative parameter trees with logical sharding axes.

A module is a (nested) dict of :class:`ParamDef`; ``init_params`` turns it
into a pytree of arrays and ``spec_tree`` into a matching pytree of
``PartitionSpec`` via logical->mesh axis rules (MaxText-style).  This keeps
model code framework-free (pure functions over dicts) while making every
parameter's sharding a first-class, greppable property.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamDef",
    "init_params",
    "spec_tree",
    "DEFAULT_RULES",
    "count_params",
]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | embed | scaled
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key: jax.Array, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        return jax.random.normal(key, d.shape, d.dtype) * d.scale
    # fan-in scaled normal (He/LeCun-ish): last-but-one axis is fan-in for
    # (in, out) matrices; fall back to first dim.
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[0]
    std = d.scale / math.sqrt(max(fan_in, 1))
    return jax.random.normal(key, d.shape, d.dtype) * std


def init_params(key: jax.Array, defs: Any) -> Any:
    """Materialize a pytree of ParamDef into arrays with per-leaf PRNG keys."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


#: canonical parameter-axis rules live in repro.dist.sharding.PARAM_RULES
#: (mutable + context-overridable, e.g. inference flips embed->None).
from repro.dist.sharding import PARAM_RULES as DEFAULT_RULES


def spec_tree(defs: Any, rules: Optional[Dict[str, Any]] = None) -> Any:
    rules = {**DEFAULT_RULES, **(rules or {})}

    def one(d: ParamDef) -> P:
        return P(*[rules.get(a) if a is not None else None for a in d.axes])

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(tree: Any) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(
        int(math.prod(x.shape)) if hasattr(x, "shape") else 0 for x in leaves
    )
