"""Rotary position embeddings: standard, partial (ChatGLM-style 2D), and
M-RoPE (Qwen2-VL: separate temporal/height/width sections)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _rot_half_pairs(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate consecutive (even, odd) channel pairs (computed f32, cast back)."""
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., 0::2], xf[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


def _angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(
    cfg: ModelConfig,
    x: jax.Array,            # (B, S, H, hd)
    positions: jax.Array,    # (B, S) or (B, S, 3) for mrope
) -> jax.Array:
    hd = x.shape[-1]
    if cfg.rope == "none":
        return x

    if cfg.rope == "rope":
        cos, sin = _angles(positions, hd, cfg.rope_theta)      # (B,S,hd/2)
        return _rot_half_pairs(x, cos[:, :, None, :], sin[:, :, None, :])

    if cfg.rope == "rope2d":
        # ChatGLM: rotary over the first half of channels only.
        rd = hd // 2
        cos, sin = _angles(positions, rd, cfg.rope_theta)
        rot = _rot_half_pairs(x[..., :rd], cos[:, :, None, :], sin[:, :, None, :])
        return jnp.concatenate([rot, x[..., rd:]], axis=-1)

    if cfg.rope == "mrope":
        # positions (B, S, 3): (t, h, w); channel sections per stream.
        st, sh, sw = cfg.mrope_sections
        assert (st + sh + sw) * 2 == hd, (cfg.mrope_sections, hd)
        inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
        ang_all = positions[..., None, :].astype(jnp.float32) * inv[None, None, :, None]
        # pick stream per channel section: [0:st]->t, [st:st+sh]->h, rest->w
        sec = jnp.concatenate(
            [
                jnp.zeros((st,), jnp.int32),
                jnp.ones((sh,), jnp.int32),
                jnp.full((sw,), 2, jnp.int32),
            ]
        )
        ang = jnp.take_along_axis(
            ang_all, sec[None, None, :, None].astype(jnp.int32), axis=-1
        )[..., 0]                                               # (B,S,hd/2)
        cos, sin = jnp.cos(ang), jnp.sin(ang)
        return _rot_half_pairs(x, cos[:, :, None, :], sin[:, :, None, :])

    raise ValueError(cfg.rope)
