"""Mixture-of-Experts block (granite-MoE style: top-k routed SwiGLU experts).

Two execution paths:

* **single-device** (unit tests / smoke, no mesh set): straightforward
  scatter/gather against a global capacity buffer.
* **distributed** (mesh set): GSPMD cannot partition a data-dependent
  scatter, so dispatch runs inside ``shard_map`` — every device routes its
  *local* tokens into a *local* (E, C_local, d) capacity buffer (exactly how
  production EP systems bound the dispatch memory), FSDP-gathers the expert
  weights over "data", computes with the f-dim sharded over "model"
  (expert-TP, granite's d_ff=512 / 16 = 32), and all-reduces the partial
  expert outputs over "model".  Capacity dropping is per-device local
  (documented deviation from global capacity; same capacity_factor).

Position-in-expert uses a double argsort over (T*K,) ids — O(TK) int32 —
instead of a (T*K, E) one-hot cumsum; scatter and combine loop over the K
routed slots so the largest float intermediate is (T_local, d).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import get_mesh, shard_map_compat as _shard_map
from repro.models.params import ParamDef


def moe_defs(cfg: ModelConfig) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, E), ("embed", "experts")),
        "wg": ParamDef((E, d, f), ("experts", "embed", "mlp")),
        "wu": ParamDef((E, d, f), ("experts", "embed", "mlp")),
        "wo": ParamDef((E, f, d), ("experts", "mlp", "embed")),
    }


def _positions_in_expert(flat_e: jax.Array, E: int) -> jax.Array:
    """pos[i] = rank of slot i among slots routed to the same expert.

    Double argsort gives each slot's rank in expert-sorted order; subtracting
    the expert's first rank (via searchsorted) yields the within-expert
    position.  O(TK log TK) compute, O(TK) int32 memory.
    """
    order = jnp.argsort(flat_e)                  # slots sorted by expert
    rank = jnp.argsort(order)                    # rank of each slot
    sorted_e = flat_e[order]
    first_rank = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    return rank - first_rank[flat_e]


def _moe_math(
    xt: jax.Array,          # (T, d) local tokens
    router: jax.Array,      # (d, E)
    wg: jax.Array,          # (E, d, f_local)
    wu: jax.Array,
    wo: jax.Array,          # (E, f_local, d)
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Route + dispatch + expert compute for one shard's tokens.

    Returns (out_partial, aux): ``out_partial`` is a PARTIAL sum over the
    f dim if wo is f-sharded (caller psums over "model").
    """
    T, d = xt.shape
    E, K = cfg.n_experts, cfg.experts_per_tok
    C = max(8, int(cfg.moe_capacity_factor * T * K / E))

    logits = jnp.einsum("td,de->te", xt, router.astype(xt.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)       # (T, E)
    gate, eidx = jax.lax.top_k(probs, K)                              # (T, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss (local; caller averages).
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    pos = _positions_in_expert(eidx.reshape(-1), E).reshape(T, K)
    keep = pos < C
    dst = jnp.where(keep, eidx * C + pos, E * C)                      # (T, K)

    # single-pass scatter: all T*K updates in one in-place pass over the
    # buffer (vs K full passes — 8x less HBM traffic at K=8)
    upd = jnp.broadcast_to(xt[:, None, :], (T, K, d)).reshape(T * K, d)
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[dst.reshape(-1)].add(upd)
    buf = buf[: E * C].reshape(E, C, d)

    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xt.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(xt.dtype))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("ecf,efd->ecd", h, wo.astype(xt.dtype))           # (E, C, d)

    # single-pass gather-combine: one gather of (T*K, d), weighted-reduced
    eo_flat = jnp.concatenate([eo.reshape(E * C, d), jnp.zeros((1, d), xt.dtype)])
    picked = eo_flat[dst.reshape(-1)].reshape(T, K, d)
    out = jnp.einsum("tkd,tk->td", picked, gate.astype(xt.dtype))
    return out, aux.astype(jnp.float32)


def apply_moe(
    p: Dict, cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    mesh = get_mesh()
    if mesh is None:
        out, aux = _moe_math(
            x.reshape(B * S, d), p["router"], p["wg"], p["wu"], p["wo"], cfg
        )
        return out.reshape(B, S, d), aux

    from repro.dist.sharding import ACT_RULES, PARAM_RULES, filter_spec

    batch_rule = ACT_RULES.get("batch", "data")
    batch_axes = batch_rule if isinstance(batch_rule, tuple) else (batch_rule,)
    if "pod" in mesh.axis_names:
        batch_axes = ("pod",) + tuple(a for a in batch_axes if a != "pod")
    emb_ax, mlp_ax = PARAM_RULES.get("embed"), PARAM_RULES.get("mlp")
    # divisibility-aware specs (decode has S=1; small smoke meshes vary)
    seq_entry = "model" if "model" not in batch_axes else None
    x_spec = filter_spec(P(batch_axes, seq_entry, None), x.shape, mesh)
    router_spec = filter_spec(P(emb_ax, None), p["router"].shape, mesh)
    w_in_spec = filter_spec(P(None, emb_ax, mlp_ax), p["wg"].shape, mesh)
    w_out_spec = filter_spec(P(None, mlp_ax, emb_ax), p["wo"].shape, mesh)

    f_sharded = w_in_spec[2] is not None
    if f_sharded:
        # expert-TP partial sums over "model" are only correct when every
        # model shard sees the SAME tokens — keep seq unsharded here.
        x_spec = P(x_spec[0], None, None)

    def local_fn(xb, router, wg, wu, wo):
        # FSDP-gather the d dim of weights (transpose = reduce-scatter
        # grads).  Cast to the compute dtype FIRST: gathering f32 master
        # weights would double the bytes on the wire for no benefit — the
        # expert matmuls run in bf16 anyway (grads still reduce in f32 via
        # the convert's transpose).
        cd = xb.dtype

        def gather(w, spec_entry, axis):
            if spec_entry is None:
                return w.astype(cd)
            names = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
            w = w.astype(cd)
            for name in reversed(names):
                w = jax.lax.all_gather(w, name, axis=axis, tiled=True)
            return w

        router = gather(router, router_spec[0], 0)
        wg = gather(wg, w_in_spec[1], 1)
        wu = gather(wu, w_in_spec[1], 1)
        wo = gather(wo, w_out_spec[2], 2)
        Bl, Sl, _ = xb.shape
        out, aux = _moe_math(xb.reshape(Bl * Sl, d), router, wg, wu, wo, cfg)
        if f_sharded:
            # expert-TP: wo's f dim is model-sharded -> partial sums
            out = jax.lax.psum(out, w_in_spec[2])
            aux = jax.lax.pmean(aux, w_in_spec[2])
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)
        if x_spec[1] == "model" and not f_sharded:
            aux = jax.lax.pmean(aux, "model")
        return out.reshape(Bl, Sl, d), aux

    fn = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, router_spec, w_in_spec, w_in_spec, w_out_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    out, aux = fn(x, p["router"], p["wg"], p["wu"], p["wo"])
    return out, aux
