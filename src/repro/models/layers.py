"""Shared building blocks: norms, MLPs, embeddings."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamDef

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_defs(cfg: ModelConfig, d: int | None = None) -> Dict:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": ParamDef((d,), ("embed_tp",), init="ones"),
            "bias": ParamDef((d,), ("embed_tp",), init="zeros"),
        }
    return {"scale": ParamDef((d,), ("embed_tp",), init="ones")}


def apply_norm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.activation == "swiglu":
        return {
            "wg": ParamDef((d, f), ("embed", "mlp")),
            "wu": ParamDef((d, f), ("embed", "mlp")),
            "wo": ParamDef((f, d), ("mlp", "embed")),
        }
    return {
        "wu": ParamDef((d, f), ("embed", "mlp")),
        "bu": ParamDef((f,), ("mlp",), init="zeros"),
        "wo": ParamDef((f, d), ("mlp", "embed")),
        "bo": ParamDef((d,), ("embed_tp",), init="zeros"),
    }


def apply_mlp(p: Dict, x: jax.Array) -> jax.Array:
    if "wg" in p:
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(x.dtype))
        u = jnp.einsum("...d,df->...f", x, p["wu"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["wu"].astype(x.dtype)) + p["bu"].astype(x.dtype)
        h = jax.nn.gelu(h)
    out = jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def pad_vocab(cfg: ModelConfig, mult: int = 2048) -> int:
    """Pad the vocab so TP sharding divides evenly (MaxText-style)."""
    return -(-cfg.vocab_size // mult) * mult


def embed_defs(cfg: ModelConfig) -> Dict:
    v = pad_vocab(cfg)
    out = {"tok": ParamDef((v, cfg.d_model), ("vocab", "embed"), init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        out["head"] = ParamDef((cfg.d_model, v), ("embed", "vocab"))
    return out


def embed_tokens(p: Dict, tokens: jax.Array, dtype) -> jax.Array:
    return p["tok"].astype(dtype)[tokens]


def logits_from(p: Dict, x: jax.Array) -> jax.Array:
    if "head" in p:
        return jnp.einsum("...d,dv->...v", x, p["head"].astype(x.dtype))
    return jnp.einsum("...d,vd->...v", x, p["tok"].astype(x.dtype))
