"""Chunked linear attention with per-step decay — shared by RWKV6 (Finch,
per-channel data-dependent decay + bonus) and Mamba2 (SSD, per-head scalar
decay).

Recurrence (state S: (dk, dv) per head):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = q_t . S_t                         (mamba-style, ``bonus=None``)
    y_t = q_t . S_{t-1} + (q_t*u).k_t v_t   (rwkv-style, ``bonus=u``)

The chunk-parallel form turns the intra-chunk part into two matmuls with a
causal mask and the inter-chunk part into a scan over chunk states — the
standard SSD/GLA decomposition, which keeps HLO cost analysis meaningful
(FLOPs live in einsums, not a length-S while loop).

Numerics: pairwise weights exp(cum_i - cum_j) are computed factored
(q*exp(cum)) . (k*exp(-cum)); with per-step log-decay clamped to
``MIN_LOG_DECAY`` and ``chunk`` = 32, |cum| <= 57.6 so both factors stay
inside float32 range while every *product* is <= 1.  Faster-than-0.165/step
decays are indistinguishable from zero-memory anyway.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

MIN_LOG_DECAY = -1.8
CHUNK = 32


def chunked_decay_attention(
    q: jax.Array,          # (B, S, H, dk)
    k: jax.Array,          # (B, S, H, dk)
    v: jax.Array,          # (B, S, H, dv)
    log_w: jax.Array,      # (B, S, H, dk) per-step log decay (<= 0)
    *,
    bonus: Optional[jax.Array] = None,   # (H, dk) rwkv "u"
    initial_state: Optional[jax.Array] = None,  # (B, H, dk, dv)
    chunk: int = CHUNK,
    return_state: bool = False,
):
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    orig_S = S
    pad = (-S) % chunk
    if pad:
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v, log_w = zpad(q), zpad(k), zpad(v), zpad(log_w)
        S = q.shape[1]
    nc = S // chunk

    f32 = jnp.float32
    qc = q.astype(f32).reshape(B, nc, chunk, H, dk).transpose(1, 0, 2, 3, 4)
    kc = k.astype(f32).reshape(B, nc, chunk, H, dk).transpose(1, 0, 2, 3, 4)
    vc = v.astype(f32).reshape(B, nc, chunk, H, dv).transpose(1, 0, 2, 3, 4)
    lw = (
        jnp.clip(log_w.astype(f32), MIN_LOG_DECAY, 0.0)
        .reshape(B, nc, chunk, H, dk)
        .transpose(1, 0, 2, 3, 4)
    )

    i_idx = jnp.arange(chunk)[:, None]
    j_idx = jnp.arange(chunk)[None, :]
    mask = (j_idx <= i_idx) if bonus is None else (j_idx < i_idx)

    S0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((B, H, dk, dv), f32)
    )

    def body(Sprev, blk):
        qb, kb, vb, lwb = blk                         # (B, Q, H, dk/dv)
        cum = jnp.cumsum(lwb, axis=1)                 # inclusive
        ecum = cum - lwb                              # exclusive
        total = cum[:, -1]                            # (B, H, dk)

        q_out = qb * jnp.exp(cum if bonus is None else ecum)
        qs = qb * jnp.exp(cum if bonus is None else ecum)
        ks = kb * jnp.exp(-cum)
        A = jnp.einsum("bihk,bjhk->bhij", qs, ks)
        A = jnp.where(mask[None, None], A, 0.0)
        y = jnp.einsum("bhij,bjhv->bihv", A, vb)
        if bonus is not None:
            diag = ((qb * bonus[None, None]) * kb).sum(-1)  # (B, Q, H)
            y = y + diag[..., None] * vb
        y = y + jnp.einsum("bihk,bhkv->bihv", q_out, Sprev)

        ks_end = kb * jnp.exp(total[:, None] - cum)   # <= 1
        Snew = Sprev * jnp.exp(total)[..., None] + jnp.einsum(
            "bihk,bihv->bhkv", ks_end, vb
        )
        return Snew, y

    S_final, ys = jax.lax.scan(body, S0, (qc, kc, vc, lw))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)[:, :orig_S]
    y = y.astype(q.dtype)
    if return_state:
        return y, S_final
    return y


def decay_attention_step(
    q1: jax.Array,         # (B, H, dk)
    k1: jax.Array,
    v1: jax.Array,         # (B, H, dv)
    log_w1: jax.Array,     # (B, H, dk)
    state: jax.Array,      # (B, H, dk, dv)
    *,
    bonus: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the same recurrence (serve path, O(1) memory)."""
    f32 = jnp.float32
    w = jnp.exp(jnp.clip(log_w1.astype(f32), MIN_LOG_DECAY, 0.0))
    kv = jnp.einsum("bhk,bhv->bhkv", k1.astype(f32), v1.astype(f32))
    if bonus is None:
        new_state = state * w[..., None] + kv
        y = jnp.einsum("bhk,bhkv->bhv", q1.astype(f32), new_state)
    else:
        y = jnp.einsum("bhk,bhkv->bhv", q1.astype(f32), state) + (
            (q1.astype(f32) * bonus[None]) * k1.astype(f32)
        ).sum(-1)[..., None] * v1.astype(f32)
        new_state = state * w[..., None] + kv
    return y.astype(q1.dtype), new_state


def decay_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
    *, bonus: Optional[jax.Array] = None,
    initial_state: Optional[jax.Array] = None,
    return_state: bool = False,
):
    """Sequential oracle (scan over time steps) for tests."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    S0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, H, dk, dv), jnp.float32)
    )

    def body(state, xs):
        q1, k1, v1, w1 = xs
        y, ns = decay_attention_step(q1, k1, v1, w1, state, bonus=bonus)
        return ns, y

    tr = lambda x: x.transpose(1, 0, 2, 3)
    Sf, ys = jax.lax.scan(body, S0, (tr(q), tr(k), tr(v), tr(log_w)))
    y = ys.transpose(1, 0, 2, 3).astype(q.dtype)
    if return_state:
        return y, Sf
    return y
