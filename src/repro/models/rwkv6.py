"""RWKV6 "Finch" block: token-shift time-mix with data-dependent per-channel
decay (LoRA-modulated) + bonus, and the squared-ReLU channel-mix FFN.

The wkv recurrence is the ``bonus`` variant of
:mod:`repro.models.linear_scan`; decode carries (shift_tm, shift_cm, wkv)
states per layer — O(1) in sequence length, which is why rwkv6-7b runs the
``long_500k`` cell.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constraint
from repro.models.linear_scan import chunked_decay_attention, decay_attention_step
from repro.models.params import ParamDef

LORA_R = 64


class RwkvState(NamedTuple):
    shift_tm: jax.Array    # (B, d) last input to time-mix
    shift_cm: jax.Array    # (B, d) last input to channel-mix
    wkv: jax.Array         # (B, H, hd, hd)


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.ssm_head_dim
    return cfg.d_model // hd, hd


def time_mix_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H, hd = _heads(cfg)
    return {
        "mu_r": ParamDef((d,), ("embed_tp",), init="zeros"),
        "mu_k": ParamDef((d,), ("embed_tp",), init="zeros"),
        "mu_v": ParamDef((d,), ("embed_tp",), init="zeros"),
        "mu_g": ParamDef((d,), ("embed_tp",), init="zeros"),
        "mu_w": ParamDef((d,), ("embed_tp",), init="zeros"),
        "wr": ParamDef((d, d), ("embed", "heads")),
        "wk": ParamDef((d, d), ("embed", "heads")),
        "wv": ParamDef((d, d), ("embed", "heads")),
        "wg": ParamDef((d, d), ("embed", "heads")),
        "w0": ParamDef((d,), ("embed_tp",), init="zeros"),
        "w_lora_a": ParamDef((d, LORA_R), ("embed", None)),
        "w_lora_b": ParamDef((LORA_R, d), (None, "embed_tp"), init="zeros"),
        "u": ParamDef((H, hd), ("heads", None), init="zeros"),
        "ln_scale": ParamDef((d,), ("embed_tp",), init="ones"),
        "wo": ParamDef((d, d), ("heads", "embed")),
    }


def channel_mix_defs(cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamDef((d,), ("embed_tp",), init="zeros"),
        "mu_r": ParamDef((d,), ("embed_tp",), init="zeros"),
        "wk": ParamDef((d, f), ("embed", "mlp")),
        "wv": ParamDef((f, d), ("mlp", "embed")),
        "wr": ParamDef((d, d), ("embed", "embed_tp")),
    }


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: x_{t-1} (prev carries the last token across steps)."""
    if prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = prev[:, None].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def apply_time_mix(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,                        # (B, S, d)
    state: Optional[RwkvState] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    B, S, d = x.shape
    H, hd = _heads(cfg)
    dt_f = x.dtype

    xs = _shift(x, state.shift_tm if state is not None else None)
    mix = lambda mu: x + (xs - x) * mu.astype(dt_f)[None, None]
    xr, xk, xv, xg, xw = (
        mix(p["mu_r"]), mix(p["mu_k"]), mix(p["mu_v"]), mix(p["mu_g"]), mix(p["mu_w"])
    )

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt_f)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt_f)).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt_f)).reshape(B, S, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt_f)))
    r = constraint(r, "batch", "seq", "heads", None)
    k = constraint(k, "batch", "seq", "heads", None)
    v = constraint(v, "batch", "seq", "heads", None)

    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(xw)))
    lora = jnp.einsum(
        "bsr,re->bse",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["w_lora_a"].astype(dt_f))),
        p["w_lora_b"].astype(dt_f),
    )
    log_w = -jnp.exp(
        jnp.clip(p["w0"][None, None].astype(jnp.float32) + lora.astype(jnp.float32), -8.0, 4.0)
    ).reshape(B, S, H, hd)
    log_w = constraint(log_w, "batch", "seq", "heads", None)

    wkv_prev = state.wkv if state is not None else None
    if S == 1 and state is not None:
        y1, wkv_new = decay_attention_step(
            r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], wkv_prev, bonus=p["u"]
        )
        y = y1[:, None]
    else:
        y, wkv_new = chunked_decay_attention(
            r, k, v, log_w,
            bonus=p["u"], initial_state=wkv_prev, return_state=True,
        )

    # per-head group norm, gate, out-projection
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + 1e-6)
    y = (yf.reshape(B, S, d) * p["ln_scale"]).astype(dt_f) * g
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(dt_f))
    out = constraint(out, "batch", "seq_res", None)

    if state is not None:
        return out, (x[:, -1].astype(state.shift_tm.dtype), wkv_new)
    return out, None


def apply_channel_mix(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    shift_prev: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    dt_f = x.dtype
    xs = _shift(x, shift_prev)
    xk = x + (xs - x) * p["mu_k"].astype(dt_f)[None, None]
    xr = x + (xs - x) * p["mu_r"].astype(dt_f)[None, None]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(dt_f))
    k = jnp.square(jax.nn.relu(k))
    k = constraint(k, "batch", "seq", "mlp")
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(dt_f))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt_f)))
    out = r * kv
    new_shift = x[:, -1] if shift_prev is not None else None
    return constraint(out, "batch", "seq_res", None), new_shift


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> RwkvState:
    H, hd = _heads(cfg)
    return RwkvState(
        shift_tm=jnp.zeros((batch, cfg.d_model), dtype),
        shift_cm=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, H, hd, hd), jnp.float32),
    )
