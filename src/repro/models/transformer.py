"""Model assembly for every assigned architecture family.

One :class:`LM` object per config exposes pure functions:

  * ``init(key) -> params``           (stacked-layer pytree, scan-ready)
  * ``param_specs(mesh) -> pytree[PartitionSpec]``
  * ``train_loss(params, batch)``     (teacher-forced CE + MoE aux)
  * ``prefill_logits(params, batch)`` (last-position logits)
  * ``decode_step(params, batch, cache) -> (logits, cache)``
  * ``init_cache(batch, max_len) / cache_specs(mesh)``

Layers are *stacked* (leading "layers" axis) and driven by ``lax.scan`` so
an 88-layer model lowers its block exactly once — the difference between a
40 s and a 40 min dry-run compile.  Remat wraps the scan body.

Families:
  dense   — [norm-attn-res, norm-mlp-res] x L (GQA/MQA, RoPE variants)
  moe     — dense attention + top-k routed experts (aux loss carried)
  ssm     — RWKV6 time-mix + channel-mix
  hybrid  — Mamba2 backbone with one *shared-weight* attention block applied
            every ``attn_every`` layers (zamba2)
  encdec  — bidirectional encoder + causal decoder with cross-attention
  vlm     — dense + M-RoPE, patch embeddings spliced into the token stream
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constraint, get_mesh, logical_spec
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import mamba2 as M2
from repro.models import rwkv6 as R6
from repro.models.attention import apply_attention, attn_defs
from repro.models.params import ParamDef, init_params, spec_tree

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def stack_defs(defs: Any, n: int) -> Any:
    """Add a leading "layers" axis to every ParamDef (for lax.scan)."""

    def one(d: ParamDef) -> ParamDef:
        return dataclasses.replace(
            d, shape=(n,) + d.shape, axes=("layers",) + d.axes
        )

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def scan_or_loop(body, carry, xs, use_scan: bool):
    """lax.scan, or an unrolled python loop over the leading axis.

    The unrolled form exists for HLO cost accounting: XLA's cost analysis
    counts a while-loop body *once*, so the roofline pipeline compiles small
    unrolled variants to recover exact per-layer costs (launch/roofline.py).
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    leaves = [x for x in jax.tree.leaves(xs) if hasattr(x, "shape")]
    n = leaves[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if all(jax.tree.structure(y).num_leaves == 0 for y in ys):
        return carry, ys[0]
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs, axis=0), *ys)
    return carry, stacked


def _remat(fn, policy: Optional[str]):
    if policy is None or policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    raise ValueError(policy)


def cross_entropy(
    logits: jax.Array, targets: jax.Array, mask: Optional[jax.Array]
) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    return nll.mean()


# ---------------------------------------------------------------------------
# per-family layer bodies.  Signature: (lp, cfg, impl, x, pos, cache_slice,
# cache_len, extra) -> (x, new_cache_slice, aux)
# ---------------------------------------------------------------------------

def _dense_block(lp, cfg, impl, x, pos, cache, cache_len, kv_override=None):
    h = L.apply_norm(lp["ln1"], x)
    a, new_cache = apply_attention(
        lp["attn"], cfg, h, pos,
        impl=impl, causal=True, cache=cache, cache_len=cache_len,
    )
    x = x + a
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(lp["ln2"], x)
    if cfg.n_experts:
        m, aux = MOE.apply_moe(lp["moe"], cfg, h)
    else:
        m = L.apply_mlp(lp["mlp"], h)
    return x + m, new_cache, aux


def _rwkv_block(lp, cfg, impl, x, pos, state, cache_len):
    st = state  # RwkvState or None
    h = L.apply_norm(lp["ln1"], x)
    a, tm_new = R6.apply_time_mix(lp["tm"], cfg, h, st)
    x = x + a
    h = L.apply_norm(lp["ln2"], x)
    m, cm_shift = R6.apply_channel_mix(
        lp["cm"], cfg, h, st.shift_cm if st is not None else None
    )
    x = x + m
    new_state = None
    if st is not None:
        new_state = R6.RwkvState(
            shift_tm=tm_new[0], shift_cm=cm_shift, wkv=tm_new[1]
        )
    return x, new_state, jnp.zeros((), jnp.float32)


def _mamba_block(lp, cfg, impl, x, state):
    h = L.apply_norm(lp["ln"], x)
    a, new_state = M2.apply_mamba(lp["mamba"], cfg, h, state)
    return x + a, new_state


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class LM:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        attn_impl: str = "naive",
        remat: Optional[str] = "full",
        rules: Optional[Dict] = None,
        scan_layers: bool = True,
    ):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.remat = remat
        self.rules = rules or {}
        self.scan_layers = scan_layers
        self.dtype = jnp.dtype(cfg.dtype)

    # -- parameter definitions ------------------------------------------------
    def _layer_defs(self) -> Dict:
        cfg = self.cfg
        if cfg.family == "ssm":
            return {
                "ln1": L.norm_defs(cfg),
                "tm": R6.time_mix_defs(cfg),
                "ln2": L.norm_defs(cfg),
                "cm": R6.channel_mix_defs(cfg),
            }
        if cfg.family == "hybrid":
            return {"ln": L.norm_defs(cfg), "mamba": M2.mamba_defs(cfg)}
        out = {
            "ln1": L.norm_defs(cfg),
            "attn": attn_defs(cfg),
            "ln2": L.norm_defs(cfg),
        }
        if cfg.n_experts:
            out["moe"] = MOE.moe_defs(cfg)
        else:
            out["mlp"] = L.mlp_defs(cfg)
        return out

    def param_defs(self) -> Dict:
        cfg = self.cfg
        defs: Dict[str, Any] = {"embed": L.embed_defs(cfg)}
        defs["final_ln"] = L.norm_defs(cfg)
        if cfg.is_encdec:
            enc_layer = {
                "ln1": L.norm_defs(cfg),
                "attn": attn_defs(cfg),
                "ln2": L.norm_defs(cfg),
                "mlp": L.mlp_defs(cfg),
            }
            dec_layer = {
                "ln1": L.norm_defs(cfg),
                "attn": attn_defs(cfg),
                "lnx": L.norm_defs(cfg),
                "xattn": attn_defs(cfg),
                "ln2": L.norm_defs(cfg),
                "mlp": L.mlp_defs(cfg),
            }
            defs["encoder"] = stack_defs(enc_layer, cfg.enc_layers)
            defs["enc_ln"] = L.norm_defs(cfg)
            defs["decoder"] = stack_defs(dec_layer, cfg.n_layers)
            return defs
        defs["layers"] = stack_defs(self._layer_defs(), cfg.n_layers)
        if cfg.family == "hybrid":
            defs["shared_attn"] = {
                "ln": L.norm_defs(cfg),
                "attn": attn_defs(cfg),
                "ln2": L.norm_defs(cfg),
                "mlp": L.mlp_defs(cfg),
            }
        return defs

    def init(self, key: jax.Array) -> Dict:
        params = init_params(key, self.param_defs())
        return jax.tree.map(lambda x: x.astype(jnp.float32), params)

    def param_specs(self) -> Any:
        return spec_tree(self.param_defs(), self.rules)

    # -- forward helpers --------------------------------------------------------
    def _embed_inputs(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = L.embed_tokens(params["embed"], tokens, self.dtype)
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(self.dtype)
            x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
        pos = batch["positions"]
        return constraint(x, "batch", "seq_res", None), pos

    def _run_decoder_stack(
        self, params, x, pos, caches, cache_len, enc_out=None, enc_len=None
    ):
        """Scan the (stacked) layer params; returns (x, new_caches, aux)."""
        cfg = self.cfg
        impl = self.attn_impl

        if cfg.is_encdec:
            def body(carry, xs):
                xc, aux = carry
                lp, cache = xs
                h = L.apply_norm(lp["ln1"], xc)
                a, c_self = apply_attention(
                    lp["attn"], cfg, h, pos,
                    impl=impl, causal=True,
                    cache=None if cache is None else cache["self"],
                    cache_len=cache_len,
                )
                xc = xc + a
                h = L.apply_norm(lp["lnx"], xc)
                kv = cache["cross"] if cache is not None else enc_out
                if cache is not None:
                    a, _ = apply_attention(
                        lp["xattn"], cfg, h, pos,
                        impl=impl, kv_override=kv, cache_len=enc_len,
                    )
                else:
                    ek, ev = self._encoder_kv(lp["xattn"], enc_out)
                    a, _ = apply_attention(
                        lp["xattn"], cfg, h, pos,
                        impl=impl, kv_override=(ek, ev), cache_len=enc_len,
                    )
                xc = xc + a
                h = L.apply_norm(lp["ln2"], xc)
                xc = xc + L.apply_mlp(lp["mlp"], h)
                new_cache = None if cache is None else {"self": c_self}
                return (xc, aux), new_cache

            body = _remat(body, self.remat)
            (x, aux), new_caches = scan_or_loop(
                body, (x, jnp.zeros((), jnp.float32)), (params["decoder"], caches),
                self.scan_layers,
            )
            return x, new_caches, aux

        if cfg.family == "ssm":
            def body(carry, xs):
                xc, aux = carry
                lp, st = xs
                xc, new_st, a = _rwkv_block(lp, cfg, impl, xc, pos, st, cache_len)
                return (xc, aux + a), new_st

            body = _remat(body, self.remat)
            (x, aux), new_caches = scan_or_loop(
                body, (x, jnp.zeros((), jnp.float32)), (params["layers"], caches),
                self.scan_layers,
            )
            return x, new_caches, aux

        if cfg.family == "hybrid":
            return self._run_hybrid(params, x, pos, caches, cache_len)

        def body(carry, xs):
            xc, aux = carry
            lp, cache = xs
            xc, new_cache, a = _dense_block(lp, cfg, impl, xc, pos, cache, cache_len)
            return (xc, aux + a), new_cache

        body = _remat(body, self.remat)
        (x, aux), new_caches = scan_or_loop(
            body, (x, jnp.zeros((), jnp.float32)), (params["layers"], caches),
            self.scan_layers,
        )
        return x, new_caches, aux

    def _encoder_kv(self, attn_params, enc_out):
        cfg = self.cfg
        k = jnp.einsum("bsd,dhk->bshk", enc_out, attn_params["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, attn_params["wv"].astype(enc_out.dtype))
        return k, v

    def _run_hybrid(self, params, x, pos, caches, cache_len):
        """Zamba2: mamba stack in groups of ``attn_every`` with the shared
        attention block between groups.  Stacked mamba params are reshaped to
        (groups, attn_every, ...) and scanned; the remainder runs after."""
        cfg = self.cfg
        impl = self.attn_impl
        every = cfg.attn_every
        n_groups, rem = divmod(cfg.n_layers, every)
        sa = params["shared_attn"]

        mamba_states = caches["mamba"] if caches is not None else None
        attn_caches = caches["attn"] if caches is not None else None

        def mamba_body(carry, xs):
            xc = carry
            lp, st = xs
            xc, new_st = _mamba_block(lp, cfg, impl, xc, st)
            return xc, new_st

        mamba_body = _remat(mamba_body, self.remat)

        def take(tree, lo, hi):
            return jax.tree.map(lambda a: a[lo:hi], tree)

        def group_reshape(tree, g, e):
            return jax.tree.map(
                lambda a: a[: g * e].reshape((g, e) + a.shape[1:]), tree
            )

        main_params = group_reshape(params["layers"], n_groups, every)
        main_states = (
            group_reshape(mamba_states, n_groups, every)
            if mamba_states is not None
            else None
        )

        def shared_block(xc, cache, clen):
            h = L.apply_norm(sa["ln"], xc)
            a, new_cache = apply_attention(
                sa["attn"], cfg, h, pos,
                impl=impl, causal=True, cache=cache, cache_len=clen,
            )
            xc = xc + a
            h = L.apply_norm(sa["ln2"], xc)
            return xc + L.apply_mlp(sa["mlp"], h), new_cache

        def group_body(carry, xs):
            xc = carry
            gp, gst, acache = xs
            xc, new_gst = scan_or_loop(mamba_body, xc, (gp, gst), self.scan_layers)
            xc, new_acache = shared_block(xc, acache, cache_len)
            return xc, (new_gst, new_acache)

        x, (new_main_states, new_attn_caches) = scan_or_loop(
            group_body, x, (main_params, main_states, attn_caches),
            self.scan_layers,
        )

        new_states = None
        if rem:
            rem_params = take(params["layers"], n_groups * every, cfg.n_layers)
            rem_states = (
                take(mamba_states, n_groups * every, cfg.n_layers)
                if mamba_states is not None
                else None
            )
            x, new_rem_states = scan_or_loop(
                mamba_body, x, (rem_params, rem_states), self.scan_layers
            )
        if mamba_states is not None:
            flat_main = jax.tree.map(
                lambda a: a.reshape((n_groups * every,) + a.shape[2:]),
                new_main_states,
            )
            if rem:
                merged = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], axis=0),
                    flat_main, new_rem_states,
                )
            else:
                merged = flat_main
            new_states = {"mamba": merged, "attn": new_attn_caches}
        return x, new_states, jnp.zeros((), jnp.float32)

    def _run_encoder(self, params, enc_embeds):
        cfg = self.cfg
        impl = self.attn_impl
        x = constraint(enc_embeds.astype(self.dtype), "batch", "seq", None)
        Se = x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(Se)[None], x.shape[:2])

        def body(carry, lp):
            xc = carry
            h = L.apply_norm(lp["ln1"], xc)
            a, _ = apply_attention(lp["attn"], cfg, h, pos, impl=impl, causal=False)
            xc = xc + a
            h = L.apply_norm(lp["ln2"], xc)
            return xc + L.apply_mlp(lp["mlp"], h), None

        body = _remat(body, self.remat)
        x, _ = scan_or_loop(body, x, params["encoder"], self.scan_layers)
        return L.apply_norm(params["enc_ln"], x)

    # -- public entry points ------------------------------------------------------
    def train_loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x, pos = self._embed_inputs(params, batch)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._run_encoder(params, batch["enc_embeds"])
        x, _, aux = self._run_decoder_stack(
            params, x, pos, None, None,
            enc_out=enc_out,
            enc_len=enc_out.shape[1] if enc_out is not None else None,
        )
        x = L.apply_norm(params["final_ln"], x)
        logits = L.logits_from(params["embed"], x)
        logits = constraint(logits, "batch", None, "vocab")
        loss = cross_entropy(logits, batch["targets"], batch.get("loss_mask"))
        if cfg.n_experts:
            loss = loss + 0.01 * aux / cfg.n_layers
        return loss

    def prefill_logits(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x, pos = self._embed_inputs(params, batch)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._run_encoder(params, batch["enc_embeds"])
        x, _, _ = self._run_decoder_stack(
            params, x, pos, None, None,
            enc_out=enc_out,
            enc_len=enc_out.shape[1] if enc_out is not None else None,
        )
        x = L.apply_norm(params["final_ln"], x[:, -1:])
        logits = L.logits_from(params["embed"], x)[:, 0]
        return constraint(logits, "batch", "vocab")

    def decode_step(self, params, batch, cache) -> Tuple[jax.Array, Any]:
        """One token for every sequence; cache carries KV / recurrent state.

        Attention caches are split (main, recent): appends land in the small
        batch-sharded recent ring (see attention.apply_attention), so the
        big kv_seq-sharded main store is never re-sharded per step."""
        cfg = self.cfg
        x, pos = self._embed_inputs(params, batch)
        split = "len_rec" in cache
        cache_len = (cache["len"], cache["len_rec"]) if split else cache["len"]
        x, new_layer_caches, _ = self._run_decoder_stack(
            params, x, pos, cache["layers"], cache_len,
            enc_len=cache.get("enc_len"),
        )
        x = L.apply_norm(params["final_ln"], x[:, -1:])
        logits = L.logits_from(params["embed"], x)[:, 0]
        new_cache = dict(cache)
        new_cache["layers"] = self._merge_layer_caches(
            cache["layers"], new_layer_caches
        )
        S = batch["tokens"].shape[1]
        if split:
            new_cache["len_rec"] = cache["len_rec"] + S
        else:
            new_cache["len"] = cache["len"] + S
        return constraint(logits, "batch", "vocab"), new_cache

    @staticmethod
    def _merge_layer_caches(old, new):
        """Scan ys carry only what changed (recent rings, recurrent
        states); graft them back onto the read-only parts (main KV stores,
        cross KV)."""
        if new is None:
            return old
        if isinstance(old, dict) and "main" in old:
            return {"main": old["main"], "recent": new["recent"]}
        if isinstance(old, dict):  # encdec {"self","cross"} / hybrid {"mamba","attn"}
            out = {}
            for k, v in old.items():
                if isinstance(new, dict) and k in new:
                    out[k] = LM._merge_layer_caches(v, new[k])
                else:
                    out[k] = v
            return out
        return new

    def flush_cache(self, cache):
        """Amortized recent->main flush: one dynamic-update-slice of the
        whole recent ring per attention cache (call every ~R decode steps;
        this is the only op that touches the kv_seq-sharded dim)."""
        if "len_rec" not in cache:
            return cache
        len_main, len_rec = cache["len"], cache["len_rec"]

        def flush(node):
            if isinstance(node, dict) and "main" in node:
                mk, mv = node["main"]
                rk, rv = node["recent"]
                ndim = mk.ndim
                idx = (0, 0, len_main) + (0,) * (ndim - 3)
                mk = jax.lax.dynamic_update_slice(mk, rk.astype(mk.dtype), idx)
                mv = jax.lax.dynamic_update_slice(mv, rv.astype(mv.dtype), idx)
                return {
                    "main": (mk, mv),
                    "recent": (jnp.zeros_like(rk), jnp.zeros_like(rv)),
                }
            return node

        new_cache = dict(cache)
        layers = cache["layers"]
        if isinstance(layers, dict) and "main" in layers:
            layers = flush(layers)
        elif isinstance(layers, dict):
            layers = {k: flush(v) for k, v in layers.items()}
        new_cache["layers"] = layers
        new_cache["len"] = len_main + len_rec
        new_cache["len_rec"] = jnp.zeros((), jnp.int32)
        return new_cache

    # -- caches ---------------------------------------------------------------------
    def init_cache(
        self, batch_size: int, max_len: int, enc_len: int = 0,
        recent_size: int = 256,
    ) -> Dict:
        cfg = self.cfg
        KV, hd, Lr = cfg.n_kv_heads, cfg.hd, cfg.n_layers
        kv_shape = (Lr, batch_size, max_len, KV, hd)
        kv_dt = jnp.dtype(cfg.kv_cache_dtype)
        R = recent_size

        def split_kv(n_stack, length):
            return {
                "main": (
                    jnp.zeros((n_stack, batch_size, length, KV, hd), kv_dt),
                    jnp.zeros((n_stack, batch_size, length, KV, hd), kv_dt),
                ),
                "recent": (
                    jnp.zeros((n_stack, batch_size, R, KV, hd), kv_dt),
                    jnp.zeros((n_stack, batch_size, R, KV, hd), kv_dt),
                ),
            }
        if cfg.is_encdec:
            cache = {
                "layers": {
                    "self": split_kv(Lr, max_len),
                    "cross": (
                        jnp.zeros((Lr, batch_size, enc_len, KV, hd), kv_dt),
                        jnp.zeros((Lr, batch_size, enc_len, KV, hd), kv_dt),
                    ),
                },
                "len": jnp.zeros((), jnp.int32),
                "len_rec": jnp.zeros((), jnp.int32),
                "enc_len": jnp.asarray(enc_len, jnp.int32),
            }
            return cache
        if cfg.family == "ssm":
            st = R6.init_rwkv_state(cfg, batch_size, self.dtype)
            stacked = R6.RwkvState(
                shift_tm=jnp.zeros((Lr,) + st.shift_tm.shape, st.shift_tm.dtype),
                shift_cm=jnp.zeros((Lr,) + st.shift_cm.shape, st.shift_cm.dtype),
                wkv=jnp.zeros((Lr,) + st.wkv.shape, st.wkv.dtype),
            )
            return {"layers": stacked, "len": jnp.zeros((), jnp.int32)}
        if cfg.family == "hybrid":
            st = M2.init_mamba_state(cfg, batch_size, self.dtype)
            n_apps = cfg.n_layers // cfg.attn_every
            return {
                "layers": {
                    "mamba": M2.MambaState(
                        conv=jnp.zeros((Lr,) + st.conv.shape, st.conv.dtype),
                        ssd=jnp.zeros((Lr,) + st.ssd.shape, st.ssd.dtype),
                    ),
                    "attn": split_kv(n_apps, max_len),
                },
                "len": jnp.zeros((), jnp.int32),
                "len_rec": jnp.zeros((), jnp.int32),
            }
        return {
            "layers": split_kv(Lr, max_len),
            "len": jnp.zeros((), jnp.int32),
            "len_rec": jnp.zeros((), jnp.int32),
        }

    def cache_spec_axes(self):
        """Logical axis names per cache leaf (for sharding the dry-run)."""
        cfg = self.cfg

        def kv_axes(leaf_ndim):
            # kv_seq carries the "model" axis; heads stay unsharded in the
            # cache (sharding both would double-book "model").
            return (None, "batch", "kv_seq", None, None)

        def split_axes():
            return {
                "main": (kv_axes(5), kv_axes(5)),
                # recent ring is batch-sharded only: its appends must never
                # touch a sharded dim
                "recent": (
                    (None, "batch", None, None, None),
                    (None, "batch", None, None, None),
                ),
            }

        if cfg.is_encdec:
            return {
                "layers": {
                    "self": split_axes(),
                    "cross": (kv_axes(5), kv_axes(5)),
                },
                "len": (),
                "len_rec": (),
                "enc_len": (),
            }
        if cfg.family == "ssm":
            return {
                "layers": R6.RwkvState(
                    shift_tm=(None, "batch", None),
                    shift_cm=(None, "batch", None),
                    wkv=(None, "batch", "heads", None, None),
                ),
                "len": (),
            }
        if cfg.family == "hybrid":
            return {
                "layers": {
                    "mamba": M2.MambaState(
                        conv=(None, "batch", None, "mlp"),
                        ssd=(None, "batch", "heads", None, None),
                    ),
                    "attn": split_axes(),
                },
                "len": (),
                "len_rec": (),
            }
        return {"layers": split_axes(), "len": (), "len_rec": ()}
