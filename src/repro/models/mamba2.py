"""Mamba2 (SSD) block — used by zamba2's backbone.

Chunk-parallel selective state space: per-head scalar decay
``a_t = exp(-exp(A_log) * dt_t)`` feeding the shared
:mod:`repro.models.linear_scan` machinery with q=C, k=B, v=dt*x.
Includes the depthwise causal conv on (x, B, C), gated RMS norm, and the
D skip connection.  Decode keeps (conv_state, ssd_state) per layer.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constraint
from repro.models.linear_scan import chunked_decay_attention, decay_attention_step
from repro.models.params import ParamDef


class MambaState(NamedTuple):
    conv: jax.Array   # (B, K-1, conv_dim)
    ssd: jax.Array    # (B, H, n_state, head_dim)


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state
    return d_in, H, cfg.ssm_head_dim, cfg.ssm_state, conv_dim


def mamba_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_in, H, hd, ns, conv_dim = _dims(cfg)
    return {
        "wz": ParamDef((d, d_in), ("embed", "mlp")),
        "wx": ParamDef((d, d_in), ("embed", "mlp")),
        "wB": ParamDef((d, ns), ("embed", "state")),
        "wC": ParamDef((d, ns), ("embed", "state")),
        "wdt": ParamDef((d, H), ("embed", "heads")),
        "dt_bias": ParamDef((H,), ("heads",), init="zeros"),
        "A_log": ParamDef((H,), ("heads",), init="zeros"),
        "D": ParamDef((H,), ("heads",), init="ones"),
        "conv_w": ParamDef((cfg.ssm_conv, conv_dim), ("conv", "mlp"), init="embed", scale=0.5),
        "norm": ParamDef((d_in,), ("mlp",), init="ones"),
        "wo": ParamDef((d_in, d), ("mlp", "embed")),
    }


def _causal_conv(xBC: jax.Array, w: jax.Array, prev: Optional[jax.Array]):
    """Depthwise causal conv along seq; returns output + new conv state."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype)
    xp = jnp.concatenate([prev, xBC], axis=1)
    out = sum(
        xp[:, i : i + xBC.shape[1]] * w[i][None, None, :] for i in range(K)
    )
    new_state = xp[:, -(K - 1) :] if K > 1 else prev
    return jax.nn.silu(out), new_state


def apply_mamba(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,                 # (B, S, d)
    state: Optional[MambaState] = None,
) -> Tuple[jax.Array, Optional[MambaState]]:
    B, S, d = x.shape
    d_in, H, hd, ns, conv_dim = _dims(cfg)
    dt_f = x.dtype

    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(dt_f))
    xi = jnp.einsum("bsd,de->bse", x, p["wx"].astype(dt_f))
    Bp = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(dt_f))
    Cp = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(dt_f))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(dt_f)).astype(jnp.float32)
        + p["dt_bias"]
    )                                                           # (B,S,H)

    xBC = jnp.concatenate([xi, Bp, Cp], axis=-1)
    xBC = constraint(xBC, "batch", "seq", "mlp")
    conv_prev = state.conv if state is not None else None
    xBC, conv_new = _causal_conv(xBC, p["conv_w"].astype(dt_f), conv_prev)
    xi, Bp, Cp = jnp.split(xBC, [d_in, d_in + ns], axis=-1)

    xh = xi.reshape(B, S, H, hd)
    v = xh * dt.astype(dt_f)[..., None]                          # (B,S,H,hd)
    q = jnp.broadcast_to(Cp[:, :, None, :], (B, S, H, ns))
    k = jnp.broadcast_to(Bp[:, :, None, :], (B, S, H, ns))
    log_w = (-jnp.exp(p["A_log"])[None, None, :] * dt)[..., None]  # (B,S,H,1)
    log_w = jnp.broadcast_to(log_w, (B, S, H, ns))
    # shard the (B,S,H,*) scan tensors over heads: the f32 chunk-scan
    # working set is the memory hot spot at zamba2 scale
    v = constraint(v, "batch", "seq", "heads", None)
    q = constraint(q, "batch", "seq", "heads", None)
    k = constraint(k, "batch", "seq", "heads", None)
    log_w = constraint(log_w, "batch", "seq", "heads", None)

    ssd_prev = state.ssd if state is not None else None
    if S == 1 and state is not None:
        y1, ssd_new = decay_attention_step(
            q[:, 0], k[:, 0], v[:, 0], log_w[:, 0], ssd_prev
        )
        y = y1[:, None]
    else:
        y, ssd_new = chunked_decay_attention(
            q, k, v, log_w, initial_state=ssd_prev, return_state=True
        )
    y = y + p["D"].astype(dt_f)[None, None, :, None] * xh
    y = y.reshape(B, S, d_in)

    # gated RMS norm then out-projection
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + 1e-6)
    y = (yf * p["norm"]).astype(dt_f) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(dt_f))
    out = constraint(out, "batch", "seq_res", None)

    new_state = (
        MambaState(conv=conv_new, ssd=ssd_new) if state is not None else None
    )
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    d_in, H, hd, ns, conv_dim = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        ssd=jnp.zeros((batch, H, ns, hd), jnp.float32),
    )
