"""Public jit'd wrappers for pud_bulk: shape-normalizing entry points used by
the KV pool, the serving engine, and the PUD microbenchmarks."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.pud_bulk import kernel as _k
from repro.kernels.pud_bulk import ref as _ref

LANES = _k.LANES


def _to_tiles(x: jax.Array) -> tuple:
    """Flatten any array to (rows, 128) int32-compatible tiles + restore info."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % (8 * LANES)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), x.shape, n


def _from_tiles(t: jax.Array, shape, n) -> jax.Array:
    return t.reshape(-1)[:n].reshape(shape)


def _dispatch(op: str, *xs: jax.Array, use_kernel: bool = True) -> jax.Array:
    tiles = [_to_tiles(x) for x in xs]
    ts = [t for t, _, _ in tiles]
    if use_kernel:
        out = _k.bulk_op(*ts, op=op)
    else:
        out = _ref.bulk_op_ref(*ts, op=op)
    return _from_tiles(out, tiles[0][1], tiles[0][2])


def pud_zero(x: jax.Array, use_kernel: bool = True) -> jax.Array:
    """RowClone zero-init (shape/dtype donor ``x``)."""
    return _dispatch("zero", x, use_kernel=use_kernel)


def pud_copy(x: jax.Array, use_kernel: bool = True) -> jax.Array:
    return _dispatch("copy", x, use_kernel=use_kernel)


def pud_not(x: jax.Array, use_kernel: bool = True) -> jax.Array:
    return _dispatch("not", x, use_kernel=use_kernel)


def pud_and(x: jax.Array, y: jax.Array, use_kernel: bool = True) -> jax.Array:
    return _dispatch("and", x, y, use_kernel=use_kernel)


def pud_or(x: jax.Array, y: jax.Array, use_kernel: bool = True) -> jax.Array:
    return _dispatch("or", x, y, use_kernel=use_kernel)


def pud_xor(x: jax.Array, y: jax.Array, use_kernel: bool = True) -> jax.Array:
    return _dispatch("xor", x, y, use_kernel=use_kernel)


def pud_maj(x: jax.Array, y: jax.Array, z: jax.Array, use_kernel: bool = True) -> jax.Array:
    return _dispatch("maj", x, y, z, use_kernel=use_kernel)


def pool_block_copy(
    pool: jax.Array, src: jax.Array, dst: jax.Array, use_kernel: bool = True
) -> jax.Array:
    """RowClone over a block pool: pool[dst] <- pool[src], in place.

    ``pool``: (num_blocks, ...) — trailing dims are flattened per block.
    """
    nb = pool.shape[0]
    flat = pool.reshape(nb, -1)
    src_dst = jnp.stack([src.astype(jnp.int32), dst.astype(jnp.int32)], axis=1)
    if use_kernel:
        out = _k.block_copy(flat, src_dst)
    else:
        out = _ref.block_copy_ref(flat, src_dst)
    return out.reshape(pool.shape)
