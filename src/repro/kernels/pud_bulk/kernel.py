"""Pallas TPU kernels for PUD-style bulk row operations.

TPU-native adaptation of the paper's substrate ops (DESIGN.md §2):

* RowClone zero / copy       -> whole-tile VMEM stores / streams,
* Ambit AND / OR / NOT       -> VPU bitwise ops on (8,128)-aligned int32
                                tiles (packed bitplanes),
* RowClone in-place block copy over a pool ("rows" = pool blocks) driven by
  a scalar-prefetched (src, dst) index list — the beam-fork / prefix-share
  path of the PUMA KV pool.

All kernels operate on buffers shaped (rows, 128): `rows` is a multiple of 8
(sublane) and blocks of ``BLOCK_ROWS`` rows are staged through VMEM.  MXU is
not involved — these are bandwidth ops; the roofline target is HBM bw, so
the only tiling decision is a VMEM-resident block large enough to amortize
grid overhead (256 rows x 128 lanes x 4 B = 128 KB per operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 256
LANES = 128

_INTERPRET = jax.devices()[0].platform != "tpu"


def _grid(rows: int, block_rows: int) -> int:
    assert rows % 8 == 0, f"rows={rows} must be 8-aligned (sublane)"
    return -(-rows // block_rows)


# -- elementwise family -------------------------------------------------------

def _zero_kernel(o_ref):
    o_ref[...] = jnp.zeros_like(o_ref)


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _not_kernel(x_ref, o_ref):
    o_ref[...] = ~x_ref[...]


def _and_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] & y_ref[...]


def _or_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] | y_ref[...]


def _xor_kernel(x_ref, y_ref, o_ref):
    # beyond-Ambit: XOR composes from AND/OR/NOT in 3 triple-activations;
    # on TPU it is a single VPU op, so expose it directly.
    o_ref[...] = x_ref[...] ^ y_ref[...]


def _maj_kernel(x_ref, y_ref, z_ref, o_ref):
    # Ambit's native primitive is MAJ(A,B,C) (triple-row activation).
    x, y, z = x_ref[...], y_ref[...], z_ref[...]
    o_ref[...] = (x & y) | (y & z) | (x & z)


_ELEMENTWISE = {
    "zero": (_zero_kernel, 0),
    "copy": (_copy_kernel, 1),
    "not": (_not_kernel, 1),
    "and": (_and_kernel, 2),
    "or": (_or_kernel, 2),
    "xor": (_xor_kernel, 2),
    "maj": (_maj_kernel, 3),
}


@functools.partial(jax.jit, static_argnames=("op", "block_rows", "interpret"))
def bulk_op(
    *operands: jax.Array,
    op: str,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
) -> jax.Array:
    """Apply a PUD bulk op over (rows, 128) int32 operands."""
    kernel, n_in = _ELEMENTWISE[op]
    if op == "zero":
        # zero takes a shape donor operand (like RowClone's reserved zero row)
        donor = operands[0]
        operands = ()
        rows = donor.shape[0]
        dtype = donor.dtype
    else:
        assert len(operands) == n_in, (op, len(operands))
        rows = operands[0].shape[0]
        dtype = operands[0].dtype
        for x in operands:
            assert x.shape == (rows, LANES), x.shape
    block_rows = min(block_rows, rows)
    grid = (_grid(rows, block_rows),)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * len(operands),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), dtype),
        interpret=_INTERPRET if interpret is None else interpret,
    )(*operands)


# -- pool block copy (RowClone over the PUMA pool) ----------------------------

def _block_copy_kernel(src_dst_ref, pool_ref, o_ref):
    del src_dst_ref  # consumed by the index maps
    o_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_copy(
    pool: jax.Array,          # (num_blocks, block_elems) — any dtype
    src_dst: jax.Array,       # (n_pairs, 2) int32
    interpret: bool | None = None,
) -> jax.Array:
    """In-place RowClone: pool[dst_i] <- pool[src_i] for each pair.

    The (src, dst) list is scalar-prefetched so the BlockSpec index maps can
    steer both the read and the aliased write; untouched blocks pass through
    via input/output aliasing — the whole pool never round-trips through the
    compute units, matching RowClone's in-DRAM semantics.
    """
    num_blocks, elems = pool.shape
    n_pairs = src_dst.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, elems), lambda i, sd: (sd[i, 0], 0)),
        ],
        out_specs=pl.BlockSpec((1, elems), lambda i, sd: (sd[i, 1], 0)),
    )
    return pl.pallas_call(
        _block_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={1: 0},  # pool aliases the output
        interpret=_INTERPRET if interpret is None else interpret,
    )(src_dst, pool)
