"""Pure-jnp oracle for the pud_bulk kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bulk_op_ref(*operands: jax.Array, op: str) -> jax.Array:
    if op == "zero":
        return jnp.zeros_like(operands[0])
    if op == "copy":
        return operands[0]
    if op == "not":
        return ~operands[0]
    if op == "and":
        return operands[0] & operands[1]
    if op == "or":
        return operands[0] | operands[1]
    if op == "xor":
        return operands[0] ^ operands[1]
    if op == "maj":
        x, y, z = operands
        return (x & y) | (y & z) | (x & z)
    raise ValueError(op)


def block_copy_ref(pool: jax.Array, src_dst: jax.Array) -> jax.Array:
    """Parallel-copy semantics (matches the kernel): every source is read
    from the *pre-op* pool, then all destinations are written.  Callers (the
    KV pool fork path) guarantee src/dst disjointness."""
    gathered = pool[src_dst[:, 0]]
    return pool.at[src_dst[:, 1]].set(gathered)
