"""Wrapper: (B, Hq, D) query layout -> grouped kernel layout, with sublane
padding of the query-head group."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import kernel as _k
from repro.kernels.paged_attention import ref as _ref


def paged_attention(
    q: jax.Array,             # (B, Hq, D)
    k_pool: jax.Array,        # (num_blocks, block_size, Hkv, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, max_blocks)
    seq_lens: jax.Array,      # (B,)
    *,
    scale: float | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    B, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    qg = q.reshape(B, Hkv, group, D)
    # pad the group dim to the 8-row sublane so VMEM scratch tiles cleanly
    gpad = (-group) % 8
    if gpad and use_kernel:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gpad), (0, 0)))
    if use_kernel:
        out = _k.paged_attention(
            qg, k_pool, v_pool,
            block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32),
            scale=scale,
        )
        out = out[:, :, :group]
    else:
        out = _ref.paged_attention_ref(
            qg, k_pool, v_pool,
            block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32),
            scale=scale,
        )
    return out.reshape(B, Hq, D)
