"""Paged decode attention over the PUMA KV pool (Pallas TPU kernel).

One query token per sequence attends to its KV stream, which lives as
``block_size``-token pages scattered through the pool and addressed by a
scalar-prefetched *block table* — the TPU replacement for the paper's
re-mmap (DESIGN.md §2).  PUMA placement makes consecutive table entries
contiguous, which turns consecutive grid steps' DMAs into sequential HBM
streams (the hardware prefetcher's fast path); the kernel itself is
placement-agnostic.

GQA layout: queries are grouped per KV head — grid (batch, kv_heads,
max_blocks), q block (group, head_dim) — so each MXU op serves a whole
query-head group against one KV page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INTERPRET = jax.devices()[0].platform != "tpu"

NEG_INF = -1e30


def _paged_kernel(
    tbl_ref, lens_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, block_size, n_blocks,
):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (group, d)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (block_size, d)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (block_size, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (group, block_size)

    pos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = pos < lens_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
    l_cur = alpha * l_scr[:, 0] + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = jnp.broadcast_to(m_cur[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur[:, None], l_scr.shape)

    @pl.when(j == n_blocks - 1)
    def _fin():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(
    q: jax.Array,            # (B, Hkv, group, D)
    k_pool: jax.Array,       # (num_blocks, block_size, Hkv, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, max_blocks) int32, -1 padded
    seq_lens: jax.Array,      # (B,) int32
    *,
    scale: float,
    interpret: bool | None = None,
) -> jax.Array:
    B, Hkv, group, D = q.shape
    _, block_size, _, _ = k_pool.shape
    max_blocks = block_tables.shape[1]

    def kv_index(b, h, j, tbl, lens):
        # -1 (pad) entries clamp to block 0; masking zeroes their weight.
        return (jnp.maximum(tbl[b, j], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, j, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_size, 1, D), kv_index),
            pl.BlockSpec((1, block_size, 1, D), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, group, D), lambda b, h, j, tbl, lens: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_kernel, scale=scale, block_size=block_size, n_blocks=max_blocks
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        interpret=_INTERPRET if interpret is None else interpret,
    )(block_tables, seq_lens, q, k_pool, v_pool)
