"""Pure-jnp oracle for paged decode attention: gathers each sequence's KV
stream out of the pool and runs dense masked attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(
    q: jax.Array,             # (B, Hkv, group, D)
    k_pool: jax.Array,        # (num_blocks, block_size, Hkv, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, max_blocks) int32, -1 padded
    seq_lens: jax.Array,      # (B,) int32
    *,
    scale: float,
) -> jax.Array:
    B, Hkv, group, D = q.shape
    _, block_size, _, _ = k_pool.shape
    max_blocks = block_tables.shape[1]
    S = max_blocks * block_size

    idx = jnp.maximum(block_tables, 0)                      # (B, nb)
    k = k_pool[idx]                                         # (B, nb, bs, Hkv, D)
    v = v_pool[idx]
    k = k.reshape(B, S, Hkv, D).transpose(0, 2, 1, 3)       # (B, Hkv, S, D)
    v = v.reshape(B, S, Hkv, D).transpose(0, 2, 1, 3)

    s = jnp.einsum(
        "bhgd,bhsd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(S)[None, None, None, :]
    mask = pos < seq_lens[:, None, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32)).astype(q.dtype)
