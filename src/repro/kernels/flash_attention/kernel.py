"""Tiled (flash) attention Pallas kernel for TPU — prefill / training path.

Online-softmax attention with GQA support.  Grid: (batch, q_heads,
q_blocks, k_blocks) with the K dimension innermost; running max / sum /
accumulator live in VMEM scratch across the K sweep.

Tiling notes (TPU):
  * q/k/v blocks are (block_q|block_k, head_dim) staged via BlockSpec; with
    the default 128x128 blocks and head_dim<=256, the working set is
    ~(2*128*256*4B)*3 < 1 MB — comfortably inside the ~16 MB/core VMEM, and
    all matmul dims are MXU-aligned (128 multiples).
  * masking (causal + KV-length) is value-based (-1e30 + multiplicative
    renorm guard) so padded and fully-masked blocks are numerically inert;
    block *skipping* for causal is a scheduling refinement recorded in
    EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INTERPRET = jax.devices()[0].platform != "tpu"

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, block_q, block_k, kv_len, n_kblocks,
):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (bq, bk)

    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = kpos < kv_len
    if causal:
        qi = pl.program_id(2)
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]                          # (bq,)
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    # `where` (not just exp) so fully-masked sweeps stay exactly zero.
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
    l_cur = alpha * l_scr[:, 0] + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = jnp.broadcast_to(m_cur[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur[:, None], l_scr.shape)

    @pl.when(ki == n_kblocks - 1)
    def _fin():
        l = l_scr[:, :1]
        o_ref[0, 0, :, :] = (
            acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "kv_len", "interpret"),
)
def flash_attention(
    q: jax.Array,   # (B, Hq, Sq, D) — Sq padded to block_q multiple
    k: jax.Array,   # (B, Hkv, Sk, D) — Sk padded to block_k multiple
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    kv_len: int | None = None,   # true (unpadded) KV length
    interpret: bool | None = None,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = (D ** -0.5) if scale is None else scale
    kv_len = Sk if kv_len is None else kv_len
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        kv_len=kv_len,
        n_kblocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D),
                lambda b, h, qi, ki, g=group: (b, h // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, D),
                lambda b, h, qi, ki, g=group: (b, h // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running sum
            pltpu.VMEM((block_q, D), jnp.float32),     # output accumulator
        ],
        interpret=_INTERPRET if interpret is None else interpret,
    )(q, k, v)
