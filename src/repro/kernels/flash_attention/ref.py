"""Pure-jnp oracle for flash_attention (materializes the score matrix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,   # (B, Hq, Sq, D)
    k: jax.Array,   # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    kv_len: int | None = None,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    scale = (D ** -0.5) if scale is None else scale
    kv_len = Sk if kv_len is None else kv_len
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    kpos = jnp.arange(Sk)[None, None, None, :]
    mask = kpos < kv_len
    if causal:
        qpos = jnp.arange(Sq)[None, None, :, None]
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> zeros
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
