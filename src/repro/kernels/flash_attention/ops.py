"""Shape-normalizing wrapper: pads sequence and head dims to kernel tiles."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(
    q: jax.Array,   # (B, Hq, Sq, D)
    k: jax.Array,   # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return _ref.attention_ref(q, k, v, causal=causal, scale=scale)
    B, Hq, Sq, D = q.shape
    Sk = k.shape[2]
    scale = (D ** -0.5) if scale is None else scale
    bq, bk = min(block_q, max(Sq, 8)), min(block_k, max(Sk, 8))
    # head_dim alignment: MXU lanes want 128 multiples (64 also supported);
    # zero-padding D is exact for both QK^T and PV.
    Dp = D if D in (64, 128) or D % 128 == 0 else -(-D // 128) * 128
    qp = _pad_to(_pad_to(q, 2, bq), 3, Dp)
    kp = _pad_to(_pad_to(k, 2, bk), 3, Dp)
    vp = _pad_to(_pad_to(v, 2, bk), 3, Dp)
    out = _k.flash_attention(
        qp, kp, vp,
        causal=causal, scale=scale, block_q=bq, block_k=bk, kv_len=Sk,
    )
    return out[:, :, :Sq, :D]
