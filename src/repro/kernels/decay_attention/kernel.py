"""Chunked decay linear attention Pallas kernel (RWKV6 / Mamba2 hot loop).

TPU-native SSD/GLA chunk recurrence: the grid walks (batch, head, chunk)
with the chunk axis innermost; the (dk, dv) state lives in VMEM scratch and
persists across grid steps for a fixed (batch, head) — TPU grids execute
sequentially, which is exactly the dependency the recurrence needs.  Each
chunk does three MXU matmuls (A = qs ks^T, y_intra = A v, state update
ks_end^T v) plus VPU exp/cumsum work; numerics follow
repro.models.linear_scan (clamped per-step log decay keeps the factored
exp(cum_i - cum_j) inside f32 range).

Layout: operands come in as (B, H, nc, Q, d) so the per-step block
(1, 1, 1, Q, d) is a clean (Q, d) VMEM tile (Q = 32 sublane-aligned,
d padded to 128 lanes by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INTERPRET = jax.devices()[0].platform != "tpu"

MIN_LOG_DECAY = -1.8
CHUNK = 32


def _decay_kernel(
    q_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scr,
    *, chunk, use_bonus,
):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)        # (Q, dk)
    k = k_ref[0, 0, 0].astype(jnp.float32)
    v = v_ref[0, 0, 0].astype(jnp.float32)        # (Q, dv)
    lw = jnp.clip(lw_ref[0, 0, 0].astype(jnp.float32), MIN_LOG_DECAY, 0.0)

    cum = jnp.cumsum(lw, axis=0)                  # inclusive (Q, dk)
    ecum = cum - lw                               # exclusive
    total = cum[-1]                               # (dk,)

    q_out_scale = jnp.exp(ecum if use_bonus else cum)
    qs = q * q_out_scale
    ks = k * jnp.exp(-cum)
    A = jax.lax.dot_general(
        qs, ks, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (Q, Q)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (j_idx < i_idx) if use_bonus else (j_idx <= i_idx)
    A = jnp.where(mask, A, 0.0)

    y = jax.lax.dot(A, v, preferred_element_type=jnp.float32)
    if use_bonus:
        u = u_ref[0].astype(jnp.float32)          # (dk,)
        diag = ((q * u[None, :]) * k).sum(-1)     # (Q,)
        y = y + diag[:, None] * v
    # inter-chunk: qs carries the same exp(cum/ecum) scaling the state needs
    y = y + jax.lax.dot(qs, state_scr[...], preferred_element_type=jnp.float32)

    ks_end = k * jnp.exp(total[None, :] - cum)    # <= 1
    state_scr[...] = state_scr[...] * jnp.exp(total)[:, None] + jax.lax.dot_general(
        ks_end, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, 0, 0] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "use_bonus", "interpret")
)
def decay_attention(
    q: jax.Array,    # (B, H, nc, Q, dk)
    k: jax.Array,
    v: jax.Array,    # (B, H, nc, Q, dv)
    log_w: jax.Array,
    u: jax.Array,    # (H, dk) — ignored unless use_bonus
    *,
    chunk: int = CHUNK,
    use_bonus: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    B, H, nc, Q, dk = q.shape
    dv = v.shape[-1]
    spec_k = pl.BlockSpec((1, 1, 1, Q, dk), lambda b, h, c: (b, h, c, 0, 0))
    spec_v = pl.BlockSpec((1, 1, 1, Q, dv), lambda b, h, c: (b, h, c, 0, 0))
    kernel = functools.partial(_decay_kernel, chunk=Q, use_bonus=use_bonus)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            spec_k, spec_k, spec_v, spec_k,
            pl.BlockSpec((1, dk), lambda b, h, c: (h, 0)),
        ],
        out_specs=spec_v,
        out_shape=jax.ShapeDtypeStruct((B, H, nc, Q, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=_INTERPRET if interpret is None else interpret,
    )(q, k, v, log_w, u)
