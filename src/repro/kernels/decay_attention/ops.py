"""Wrapper: (B, S, H, d) layout -> chunked kernel layout with padding.

The pure-jnp oracle is repro.models.linear_scan.chunked_decay_attention /
decay_attention_ref (the model path the kernel replaces on real TPUs).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decay_attention import kernel as _k
from repro.models.linear_scan import decay_attention_ref


def decay_attention(
    q: jax.Array,          # (B, S, H, dk)
    k: jax.Array,
    v: jax.Array,          # (B, S, H, dv)
    log_w: jax.Array,      # (B, S, H, dk)
    *,
    bonus: Optional[jax.Array] = None,   # (H, dk) rwkv "u"
    chunk: int = _k.CHUNK,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        return decay_attention_ref(q, k, v, log_w, bonus=bonus)
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    pad = (-S) % chunk
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v, log_w = zp(q), zp(k), zp(v), zp(log_w)
    nc = q.shape[1] // chunk

    def to_kernel(x, d):
        return (
            x.reshape(B, nc, chunk, H, d).transpose(0, 3, 1, 2, 4)
        )  # (B, H, nc, Q, d)

    u = bonus if bonus is not None else jnp.zeros((H, dk), q.dtype)
    out = _k.decay_attention(
        to_kernel(q, dk), to_kernel(k, dk), to_kernel(v, dv), to_kernel(log_w, dk),
        u.astype(q.dtype),
        chunk=chunk, use_bonus=bonus is not None,
    )
    out = out.transpose(0, 2, 3, 1, 4).reshape(B, nc * chunk, H, dv)
    return out[:, :S]
