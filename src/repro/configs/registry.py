"""Architecture registry: ``get_config(name)`` / ``ARCHS`` (all assigned).

``puma_paper`` is the one non-LM entry: ``get_config("puma_paper")``
returns a :class:`repro.configs.puma_paper.PumaPaperConfig` — the paper's
DRAM organization (channel/bank/subarray counts) validated against
``DramGeometry`` and both interleave schemes at construction.  Use
``.geometry()`` / ``.address_map()`` on it; ``lm_archs()`` excludes it."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, RunShape, SHAPES

ARCHS: List[str] = [
    "granite_moe_3b_a800m",
    "granite_moe_1b_a400m",
    "zamba2_7b",
    "seamless_m4t_medium",
    "granite_34b",
    "stablelm_1_6b",
    "mistral_nemo_12b",
    "chatglm3_6b",
    "qwen2_vl_72b",
    "rwkv6_7b",
    "puma_paper",          # the paper's own PUD micro-benchmark "arch"
]


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def lm_archs() -> List[str]:
    return [a for a in ARCHS if a != "puma_paper"]


#: the registry models the trace/offload benchmark prices a decode step for
#: (one small dense, one MoE — exercising expert dispatch — one GQA dense);
#: the ISSUE-10 coverage floor is "≥3 registry models x 4 allocators".
TRACE_ARCHS: List[str] = [
    "stablelm_1_6b",
    "granite_moe_1b_a400m",
    "chatglm3_6b",
]


def moe_archs() -> List[str]:
    """Architectures with a routed-expert MLP (MoE expert dispatch)."""
    return [a for a in lm_archs() if get_config(a).n_experts > 0]


def cells(arch: str) -> Dict[str, RunShape]:
    """The assigned (shape -> RunShape) cells for one arch, with skips."""
    cfg = get_config(arch)
    out = {}
    for sname, shape in SHAPES.items():
        if sname == "long_500k" and not cfg.sub_quadratic:
            continue  # quadratic attention: skipped per assignment (DESIGN.md)
        out[sname] = shape
    return out
