"""The paper's own evaluated substrate: Ambit/RowClone PUD over an 8 GB
DDR system — not an LM; selected by the PUD micro-benchmarks."""
from repro.core.dram import DramGeometry

CONFIG = DramGeometry()
