"""The paper's own evaluated substrate: Ambit/RowClone PUD over an 8 GB
DDR system — not an LM; selected by the PUD micro-benchmarks.

``PumaPaperConfig`` exposes the DRAM organization — including the channel
and bank counts the channel-parallel executor scales over — as plain config
fields with the paper's defaults (one channel/rank of x64 devices,
8 banks x 1024 subarrays x 1024 rows x 1 KB rows = 8 GB).  The fields are
validated against :class:`~repro.core.dram.DramGeometry` *and* both
interleave schemes at construction, so a bad channel/bank count fails with
a clear error here instead of silently mis-decoding addresses later.
"""
from __future__ import annotations

import dataclasses

from repro.core.dram import (
    AddressMap,
    BANK_REGION_SCHEME,
    CACHELINE_INTERLEAVED_SCHEME,
    DramGeometry,
)

__all__ = ["PumaPaperConfig", "CONFIG"]


@dataclasses.dataclass(frozen=True)
class PumaPaperConfig:
    """DRAM organization knobs (paper §2(i) platform information)."""

    channels: int = 1
    ranks_per_channel: int = 1
    banks_per_rank: int = 8
    subarrays_per_bank: int = 1024
    rows_per_subarray: int = 1024       # paper footnote 1
    row_bytes_per_chip: int = 1024      # 1024 columns x 8 bits
    chips_per_rank: int = 1

    def geometry(self) -> DramGeometry:
        """The validated :class:`DramGeometry` for these fields."""
        return DramGeometry(**dataclasses.asdict(self))

    def address_map(self, scheme=None) -> AddressMap:
        return AddressMap(self.geometry(), scheme)

    def __post_init__(self):
        # Validate eagerly: every field must be a power of two (DramGeometry
        # checks that) and both interleave schemes must cover the resulting
        # address space exactly (AddressMap checks the bit budget).  A
        # mistyped channel/bank count dies here with the offending field
        # named, not later as a silent mis-decode.
        try:
            geo = self.geometry()
            for scheme in (BANK_REGION_SCHEME, CACHELINE_INTERLEAVED_SCHEME):
                AddressMap(geo, scheme)
        except (ValueError, AssertionError) as e:
            raise ValueError(
                f"invalid PUMA DRAM configuration {dataclasses.asdict(self)}: {e}"
            ) from e


CONFIG = PumaPaperConfig()
