"""Config schema for architectures and run shapes.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<id>.py``; ``smoke()`` derives the reduced-config variant
used by per-arch CPU smoke tests.  Run shapes (the assigned seq/batch cells)
are :class:`RunShape` instances in ``SHAPES``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "RunShape", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # positional encoding
    rope: str = "rope"                      # rope | rope2d | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of half-dims
    # MoE
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba2) / linear attention (rwkv6)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # hybrid (zamba2-style): one shared attention block applied every k layers
    attn_every: int = 0
    # encoder-decoder
    enc_layers: int = 0                     # >0 => enc-dec; n_layers = decoder
    cross_attention: bool = False
    # misc
    activation: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"   # "float8_e4m3fn" = quantized KV pages
    # frontends ([audio]/[vlm]): backbone consumes precomputed embeddings
    frontend: Optional[str] = None          # None | audio | vision
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k cell? (SSM/hybrid/linear-attn)"""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.activation == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.n_experts:
            mlp = self.n_experts * (3 * d * f) + d * self.n_experts
        if self.family == "ssm":  # rwkv6-style block
            att_d = d
            attn = 4 * d * att_d + att_d * d + 6 * d * 32 * 2  # rkvg + out + lora-ish mixers
        per_layer = attn + mlp + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = L * per_layer + emb
        if self.is_encdec:
            enc_per = attn + mlp + 2 * d
            total += self.enc_layers * enc_per + L * attn  # cross-attn
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            mamba = d * (2 * d_in + 2 * nh) + d_in * d + nh * self.ssm_state * 0
            total = L * (mamba + 2 * d) + emb
            # shared attention block (counted once - weights shared)
            total += attn + 3 * d * f
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        mlp_active = self.experts_per_tok * (3 * d * f) + d * self.n_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(L * (attn + mlp_active + 2 * d) + emb)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, min(3, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=64 if self.n_experts else 256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            experts_per_tok=min(self.experts_per_tok, 2) if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            enc_layers=2 if self.enc_layers else 0,
            attn_every=2 if self.attn_every else 0,
            mrope_sections=(4, 6, 6),
            dtype="float32",
            kv_cache_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class RunShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES = {
    "train_4k": RunShape("train_4k", 4096, 256, "train"),
    "prefill_32k": RunShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": RunShape("decode_32k", 32768, 128, "decode"),
    "long_500k": RunShape("long_500k", 524288, 1, "decode"),
}
