"""zamba2-7b [hybrid]: 81L d_model=3584 Mamba2 backbone (ssm_state=64) with a
shared attention block (32H, GQA kv=32, d_ff=14336) every 6 layers.
[arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    rope="rope",
    notes="shared-weight attn block every 6 mamba layers; simplified input "
          "(no concat-with-embedding, see DESIGN.md)",
)
