"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2D (half-channel) RoPE.  [arXiv:2406.12793; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope="rope2d",
)
