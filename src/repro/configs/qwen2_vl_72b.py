"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution.  Vision frontend is a STUB:
input_specs() provides precomputed patch embeddings spliced into the token
stream.  [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope="mrope",
    mrope_sections=(16, 24, 24),
    frontend="vision",
)
