"""seamless-m4t-medium [audio]: enc-dec, 12L each, d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206.  Audio frontend is a STUB: input_specs() provides
precomputed frame embeddings.  [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    enc_layers=12,
    cross_attention=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    rope="none",
    activation="gelu",
    norm="layernorm",
    frontend="audio",
)
