"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512,
vocab 49155, 40 experts top-8.  [hf:ibm-granite/granite-3.0-*; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    experts_per_tok=8,
    rope="rope",
    tie_embeddings=True,
    notes="granite MoE: per-expert SwiGLU d_ff=512; expert-TP sharding",
)
