"""Serving launcher: ``python -m repro.launch.serve --arch stablelm_1_6b``.

Continuous batching over the PUMA paged KV pool on the reduced config
(CPU container); ``--policy`` compares placement policies.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config, lm_archs
from repro.core.kv_pool import KVPoolConfig
from repro.models.transformer import LM
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1_6b", choices=lm_archs())
    ap.add_argument("--policy", default="puma",
                    choices=["puma", "first_fit", "random"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seqs", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    if cfg.family in ("ssm", "hybrid", "encdec"):
        raise SystemExit(
            f"{args.arch}: paged-KV serving applies to attention-KV archs; "
            "SSM/hybrid state serving uses the dense decode path "
            "(see DESIGN.md §Arch-applicability)"
        )
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    pool_cfg = KVPoolConfig(
        num_blocks=512, block_size=8, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        n_layers=cfg.n_layers, max_seqs=args.max_seqs, max_blocks_per_seq=32,
        blocks_per_arena=64, policy=args.policy, dtype="float32",
    )
    eng = ServeEngine(model, params, pool_cfg, use_kernel=False)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=list(rng.integers(0, cfg.vocab_size, int(rng.integers(8, 64)))),
            max_new=args.max_new,
        ))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    m = eng.metrics()
    print(
        f"[serve] {args.arch} policy={args.policy}: {len(done)} requests, "
        f"{int(m['tokens'])} tokens, {m['tokens']/dt:.1f} tok/s | "
        f"contiguity={m['mean_contiguous_fraction']:.3f} "
        f"descriptors/tile={m['descriptors_per_tile']:.3f}"
    )


if __name__ == "__main__":
    main()
