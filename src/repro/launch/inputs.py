"""Input builders for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, no allocation) for the dry-run; ``make_batch`` builds
concrete arrays for smoke tests / examples.  Modality frontends are stubs
per the assignment: [audio] provides precomputed frame embeddings, [vlm]
precomputed patch embeddings (spliced over the first positions).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunShape

N_PATCHES = 256  # vlm stub: patch embeddings replace the first 256 positions


def _pos_shape(cfg: ModelConfig, B: int, S: int) -> Tuple[int, ...]:
    return (B, S, 3) if cfg.rope == "mrope" else (B, S)


def batch_shapes(cfg: ModelConfig, shape: RunShape) -> Dict[str, Any]:
    """Name -> (shape, dtype) for the step-function ``batch`` argument."""
    B = shape.global_batch
    S = 1 if shape.is_decode else shape.seq_len
    out: Dict[str, Any] = {
        "tokens": ((B, S), jnp.int32),
        "positions": (_pos_shape(cfg, B, S), jnp.int32),
    }
    if shape.mode == "train":
        out["targets"] = ((B, S), jnp.int32)
        out["loss_mask"] = ((B, S), jnp.float32)
    if cfg.frontend == "vision" and not shape.is_decode:
        out["patch_embeds"] = ((B, min(N_PATCHES, S), cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec and not shape.is_decode:
        out["enc_embeds"] = ((B, shape.seq_len, cfg.d_model), jnp.bfloat16)
    return out


def input_specs(cfg: ModelConfig, shape: RunShape) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in batch_shapes(cfg, shape).items()
    }


def abstract_cache(model, shape: RunShape):
    """ShapeDtypeStruct pytree for the decode cache of one cell."""
    B = shape.global_batch
    enc_len = shape.seq_len if model.cfg.is_encdec else 0
    return jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, enc_len=enc_len)
    )


def make_batch(
    cfg: ModelConfig, shape: RunShape, seed: int = 0
) -> Dict[str, jax.Array]:
    """Concrete random batch (smoke tests, examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, (s, d) in batch_shapes(cfg, shape).items():
        if k in ("tokens", "targets"):
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab_size, size=s), d)
        elif k == "positions":
            B, S = s[0], s[1]
            base = np.broadcast_to(np.arange(S)[None], (B, S))
            if len(s) == 3:
                base = np.broadcast_to(base[..., None], (B, S, 3))
            out[k] = jnp.asarray(base.copy(), d)
        elif k == "loss_mask":
            out[k] = jnp.ones(s, d)
        else:  # frontend embeddings
            out[k] = jnp.asarray(rng.normal(size=s) * 0.02, d)
    return out
