"""HLO text statistics: collective bytes, op census, remat duplication.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled per-device HLO module: for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute we sum the *result* buffer
sizes (per-shard bytes actually crossing links on this device, counting each
async start/done pair once).
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
# e.g.  %ar = (f32[128]{0}, f32[64,8]{1,0}) all-reduce-start(...)
_OP_RE = re.compile(
    r"=\s*(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: {count, bytes} (result-buffer bytes, per device)."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0} for k in COLLECTIVES
    }
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_types, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # counted at -start
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(result_types)
    return out


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def op_census(hlo_text: str, ops=("dot", "convolution", "fusion")) -> Counter:
    c: Counter = Counter()
    for op in ops:
        c[op] = len(re.findall(rf"= [^=]*?\b{op}\(", hlo_text))
    return c


# Opcodes whose operands/results genuinely move through HBM on a fused TPU
# pipeline.  Elementwise chains are assumed fused away (XLA-CPU leaves them
# unfused, which makes raw `bytes accessed` a ~5-10x over-estimate of TPU
# HBM traffic).
_MEMORY_OPS = (
    "dot", "convolution", "fusion", "custom-call",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
    "reduce", "reduce-window", "sort", "concatenate", "copy", "transpose",
)
_OPCODE_RE = re.compile(
    r"=\s*(?:\([^()]*\)|\S+)\s*([a-z][a-z0-9-]*)\("
)


def _literals(line: str):
    out = []
    for dtype, dims in _SHAPE_RE.findall(line):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dtype])
    return out


def fused_bytes_estimate(hlo_text: str) -> float:
    """TPU-fusion-aware HBM-traffic estimate.

    Sums the bytes that genuinely cross HBM per opcode class, assuming
    (i) elementwise chains fuse away (XLA-CPU leaves them unfused, making
    raw `bytes accessed` a ~5-10x over-estimate) and (ii) scatter /
    dynamic-update-slice execute in place (touched rows only, not a full
    buffer rewrite).  Loop bodies count once; callers extrapolate.
    Per line, literal[0] is the result type, the rest are operand types.
    """
    total = 0.0
    for line in hlo_text.splitlines():
        m = _OPCODE_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        base = op.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES or op.endswith("-done"):
            continue  # collectives live in their own roofline term
        lits = _literals(line)
        if not lits:
            continue
        res, ops_ = lits[0], lits[1:]
        if base in ("dot", "convolution", "fusion", "custom-call",
                    "sort", "concatenate", "reduce-window"):
            total += res + sum(ops_)
        elif base in ("transpose", "dynamic-slice", "reverse"):
            total += 2 * res
        elif base == "copy":
            # XLA-CPU bufferization copies (around in-place scatter, loop
            # carries, donation): elided or absorbed on TPU — excluded; the
            # unfused `bytes accessed` upper bound still includes them.
            continue
        elif base == "gather":
            # read gathered rows + indices, write result
            total += 2 * res + (ops_[1] if len(ops_) > 1 else 0)
        elif base == "scatter":
            # in place: read+write touched rows (~updates), read indices
            upd = ops_[-1] if ops_ else 0
            idx = ops_[1] if len(ops_) > 2 else 0
            total += 2 * upd + idx
        elif base == "dynamic-update-slice":
            upd = ops_[1] if len(ops_) > 1 else 0
            total += 2 * upd
        elif base == "reduce":
            total += res + (ops_[0] if ops_ else 0)
    return float(total)
