import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is the multi-pod dry-run driver:
# for every (architecture x input-shape x mesh) cell it lowers + compiles the
# real step function against ShapeDtypeStruct inputs, proving the sharding
# config is coherent at 256/512 chips, and records memory / cost / collective
# statistics for EXPERIMENTS.md.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_vl_72b \
#       --shape train_4k --mesh single --attn chunked
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, RunShape
from repro.configs.registry import cells, get_config, lm_archs
from repro.dist import sharding as shd
from repro.launch import hlo_stats
from repro.launch.inputs import abstract_cache, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import LM
from repro.optim import adamw as opt_mod
from repro.train.step import build_train_step

RESULTS_PATH = "experiments/dryrun_results.json"

#: gradient-accumulation microbatching for train cells ("auto"): sized so the
#: per-device live set (logits + per-layer remat carries) fits v5e's 16 GB.
ACCUM_DEFAULTS = {
    "qwen2_vl_72b": 16,
    "granite_34b": 8,
    "mistral_nemo_12b": 4,
    "zamba2_7b": 4,
    "chatglm3_6b": 4,
    "rwkv6_7b": 4,
    "granite_moe_3b_a800m": 4,
    "granite_moe_1b_a400m": 4,
}

#: long-context decode has global_batch=1, so the "data" axis is idle —
#: spread the KV-cache length over BOTH axes (32k-per-shard pages).
SHAPE_RULES = {"long_500k": {"kv_seq": ("model", "data")}}


def auto_accum(arch: str, shape: RunShape) -> int:
    if shape.mode != "train":
        return 1
    return ACCUM_DEFAULTS.get(arch, 2)


#: decode cells whose bf16 KV cache cannot fit 16 GB/chip even fully
#: sharded: store KV pages quantized (fp8), computing in bf16 on read.
KV_DTYPE_DEFAULTS = {("qwen2_vl_72b", "decode_32k"): "float8_e4m3fn"}


def _batch_shardings(ispecs: Dict, mesh) -> Dict:
    axes = {
        k: ("batch",) + (None,) * (len(v.shape) - 1) for k, v in ispecs.items()
    }
    return shd.axes_to_shardings(axes, ispecs, mesh)


def build_cell(
    cfg: ModelConfig,
    shape: RunShape,
    mesh,
    *,
    attn_impl: str = "chunked",
    remat: str = "full",
    scan_layers: bool = True,
    rules: Optional[Dict] = None,
    accum_steps: int = 1,
):
    """Returns (jitted_fn, abstract_args) for one cell under ``mesh``."""
    model = LM(cfg, attn_impl=attn_impl, remat=remat, scan_layers=scan_layers)
    shd.set_mesh(mesh)
    if rules:
        shd.ACT_RULES.update(rules)  # caller restores (see run_cell)

    params_abs = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    if shape.mode != "train":
        # serving checkpoints are bf16 (f32 master weights only exist in the
        # optimizer state); at 72B TP-16 that's 9 GB/chip instead of 18.
        params_abs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(
                a.shape, jnp.bfloat16 if a.dtype == jnp.float32 else a.dtype
            ),
            params_abs,
        )
    pshard = shd.shardings_for(model.param_specs(), params_abs, mesh)
    params_abs = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        params_abs, pshard,
    )
    ispecs = input_specs(cfg, shape)
    bshard = _batch_shardings(ispecs, mesh)
    batch_abs = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bshard[k])
        for k, v in ispecs.items()
    }

    if shape.mode == "train":
        ocfg = opt_mod.AdamWConfig()
        step = build_train_step(model, ocfg, accum_steps=accum_steps)
        opt_abs = jax.eval_shape(opt_mod.init_opt_state, params_abs)
        # moments share the param specs; step counter replicated
        mu_shard = pshard
        nu_shard = pshard
        opt_abs = opt_mod.OptState(
            mu=jax.tree.map(
                lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
                opt_abs.mu, mu_shard,
            ),
            nu=jax.tree.map(
                lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
                opt_abs.nu, nu_shard,
            ),
            step=opt_abs.step,
        )
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn, (params_abs, opt_abs, batch_abs), model

    if shape.mode == "prefill":
        fn = jax.jit(model.prefill_logits)
        return fn, (params_abs, batch_abs), model

    # decode
    cache_abs = abstract_cache(model, shape)
    cshard = shd.axes_to_shardings(model.cache_spec_axes(), cache_abs, mesh)
    cache_abs = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        cache_abs, cshard,
    )
    fn = jax.jit(model.decode_step, donate_argnums=(2,))
    return fn, (params_abs, batch_abs, cache_abs), model


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    attn_impl: str = "chunked",
    remat: str = "full",
    scan_layers: bool = True,
    n_layers: Optional[int] = None,
    rules: Optional[Dict] = None,
    keep_hlo: bool = False,
    accum_steps: Optional[int] = None,
    param_rules: Optional[Dict] = None,
    cfg_overrides: Optional[Dict] = None,
) -> Dict[str, Any]:
    """Lower + compile one cell; return the dry-run record."""
    cfg = get_config(arch)
    overrides = {}
    if n_layers is not None:
        overrides["n_layers"] = n_layers
        if cfg.is_encdec:
            overrides["enc_layers"] = n_layers
    if (arch, shape_name) in KV_DTYPE_DEFAULTS:
        overrides["kv_cache_dtype"] = KV_DTYPE_DEFAULTS[(arch, shape_name)]
    if cfg_overrides:
        overrides.update(cfg_overrides)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if accum_steps is None:
        accum_steps = auto_accum(arch, shape)
    eff_rules = dict(SHAPE_RULES.get(shape_name, {}))
    eff_rules.update(rules or {})
    # inference keeps FSDP param sharding (2D: embed x TP): replicated
    # bf16 weights make GSPMD/scan materialize full-stack temporaries; the
    # per-layer gather is the honest, overlappable cost (see §Perf).
    eff_param_rules = dict(param_rules or {})

    t0 = time.time()
    with shd.override_rules(**eff_rules), shd.override_param_rules(**eff_param_rules):
        fn, args, model = build_cell(
            cfg, shape, mesh,
            attn_impl=attn_impl, remat=remat, scan_layers=scan_layers,
            accum_steps=accum_steps,
        )
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = hlo_stats.collective_stats(hlo)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "attn_impl": attn_impl,
        "remat": remat,
        "scan_layers": scan_layers,
        "accum_steps": accum_steps,
        "kv_cache_dtype": cfg.kv_cache_dtype,
        "n_layers": cfg.n_layers,
        "mode": shape.mode,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "fused_bytes": hlo_stats.fused_bytes_estimate(hlo),
            "collective_bytes": sum(v["bytes"] for v in colls.values()),
        },
        "collectives": colls,
        "status": "ok",
    }
    if keep_hlo:
        rec["hlo_text"] = hlo
    return rec


def _load(path: str) -> Dict[str, Any]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def _key(arch, shape, mesh_name, attn):
    return f"{arch}|{shape}|{mesh_name}|{attn}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--attn", default="chunked", choices=["naive", "chunked"])
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default=RESULTS_PATH)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = lm_archs() if args.arch == "all" else [args.arch.replace("-", "_")]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = _load(args.out)

    for arch in archs:
        shape_names = (
            list(cells(arch)) if args.shape == "all" else [args.shape]
        )
        for shape_name in shape_names:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                key = _key(arch, shape_name, mesh_name, args.attn)
                if key in results and results[key].get("status") == "ok" and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[cell] {key} ...", flush=True)
                try:
                    rec = run_cell(
                        arch, shape_name, multi,
                        attn_impl=args.attn, remat=args.remat,
                    )
                except Exception as e:  # noqa: BLE001 — record the failure
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"[FAIL] {key}: {rec['error']}")
                else:
                    pd = rec["per_device"]
                    print(
                        f"[ok]   {key}: compile={rec['compile_s']}s "
                        f"peak={pd['peak_bytes']/2**30:.2f}GiB "
                        f"flops={pd['flops']:.3g} coll={pd['collective_bytes']:.3g}B"
                    )
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
