"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model") — TPU v5e pod.
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries pure data parallelism across the DCN/ICI boundary;
FSDP stays inside a pod ("data"), tensor/expert parallelism inside a
16-chip ring ("model").

This is a FUNCTION (not a module-level constant) so importing never touches
jax device state — the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with ``AxisType.Auto`` where the installed jax has
    it (``axis_types`` landed after 0.4.x); a plain mesh otherwise.  Keeps
    one mesh-construction path working across the jax versions the repo
    sees (CPU container vs real-hardware toolchains)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_smoke_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CPU integration tests (8 forced host devices)."""
    return make_mesh_compat((n_data, n_model), ("data", "model"))
