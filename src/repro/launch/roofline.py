import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Roofline analysis (EXPERIMENTS.md §Roofline).
#
# Terms per (arch x shape) on the single-pod mesh, all PER DEVICE per step
# (cost_analysis of the partitioned module is per-device — calibrated in
# EXPERIMENTS.md §Methodology):
#
#   compute_s    = HLO_flops / peak_flops          (197 TFLOP/s bf16, v5e)
#   memory_s     = HLO_bytes_accessed / hbm_bw     (819 GB/s)
#   collective_s = collective_bytes / ici_bw       (50 GB/s/link)
#
# XLA counts a lax.scan body ONCE, so scanned-layer models under-report.
# We recover exact totals by compiling small UNROLLED variants and solving
# the linear model  F(L) = A + L*B  (dense/moe/ssm/vlm/encdec), or
# F = A + Lm*Bm + n_app*Ba for the hybrid (mamba layers + shared-attn
# applications).  A is the fixed cost (embed, logits, loss, optimizer),
# B the per-layer cost; every reported quantity (flops, bytes, collective
# bytes) is extrapolated with the same coefficients.  Peak memory comes from
# the full-size scanned dry-run compile (no extrapolation).
import argparse
import json
from typing import Any, Dict, Optional

from repro.configs.base import SHAPES
from repro.configs.registry import cells, get_config, lm_archs
from repro.launch.dryrun import run_cell

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link
CHIPS = 256             # single-pod roofline

METRICS = ("flops", "bytes_accessed", "fused_bytes", "collective_bytes")

ROOFLINE_PATH = "experiments/roofline_results.json"


def _pd(rec: Dict) -> Dict[str, float]:
    return {m: float(rec["per_device"][m]) for m in METRICS}


def _lin2(f1: Dict, f2: Dict) -> Dict[str, Dict[str, float]]:
    """F(L) = A + L*B from L=1,2 samples."""
    B = {m: f2[m] - f1[m] for m in METRICS}
    A = {m: f1[m] - B[m] for m in METRICS}
    return {"A": A, "B": B}


def extrapolate(arch: str, shape_name: str, *, attn_impl: str) -> Dict[str, Any]:
    """Per-device totals for the full layer count, via unrolled variants.

    ``accum_steps=1``: the microbatch loop is a lax.scan whose body the HLO
    cost analysis would count once; with no accumulation the totals cover
    the full global batch directly."""
    cfg = get_config(arch)
    kw = dict(attn_impl=attn_impl, scan_layers=False, multi_pod=False,
              accum_steps=1)

    if cfg.family == "hybrid":
        every = cfg.attn_every
        f6 = _pd(run_cell(arch, shape_name, n_layers=every, **kw))
        f7 = _pd(run_cell(arch, shape_name, n_layers=every + 1, **kw))
        f12 = _pd(run_cell(arch, shape_name, n_layers=2 * every, **kw))
        Bm = {m: f7[m] - f6[m] for m in METRICS}
        Ba = {m: f12[m] - f6[m] - every * Bm[m] for m in METRICS}
        A = {m: f6[m] - every * Bm[m] - Ba[m] for m in METRICS}
        L = cfg.n_layers
        n_app = L // every
        total = {m: A[m] + L * Bm[m] + n_app * Ba[m] for m in METRICS}
        return {
            "total": total,
            "fixed": A,
            "per_layer": Bm,
            "per_attn_app": Ba,
            "samples": {"L6": f6, "L7": f7, "L12": f12},
        }

    f1 = _pd(run_cell(arch, shape_name, n_layers=1, **kw))
    f2 = _pd(run_cell(arch, shape_name, n_layers=2, **kw))
    co = _lin2(f1, f2)
    L = cfg.n_layers
    total = {m: co["A"][m] + L * co["B"][m] for m in METRICS}
    return {
        "total": total,
        "fixed": co["A"],
        "per_layer": co["B"],
        "samples": {"L1": f1, "L2": f2},
    }


def model_flops_per_device(arch: str, shape_name: str) -> Dict[str, float]:
    """Useful-work floor: 6*N_active*D (train) / 2*N_active*D (inference)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.n_active_params()
    tokens = (
        shape.global_batch
        if shape.is_decode
        else shape.global_batch * shape.seq_len
    )
    mult = 6 if shape.mode == "train" else 2
    return {
        "n_active_params": n,
        "tokens_per_step": tokens,
        "model_flops_per_device": mult * n * tokens / CHIPS,
    }


def roofline_cell(
    arch: str,
    shape_name: str,
    *,
    attn_impl: str = "chunked",
    dryrun_record: Optional[Dict] = None,
) -> Dict[str, Any]:
    ext = extrapolate(arch, shape_name, attn_impl=attn_impl)
    tot = ext["total"]
    compute_s = tot["flops"] / PEAK_FLOPS
    # memory term uses the fusion-aware HBM-traffic estimate; the raw
    # unfused `bytes accessed` is kept as an upper bound
    memory_s = tot["fused_bytes"] / HBM_BW
    collective_s = tot["collective_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(arch, shape_name)
    bound = max(terms.values())
    rec = {
        "arch": arch,
        "shape": shape_name,
        "attn_impl": attn_impl,
        "mesh": "16x16",
        **terms,
        "memory_s_unfused_bound": tot["bytes_accessed"] / HBM_BW,
        "dominant": dominant,
        "useful_flops_ratio": (
            mf["model_flops_per_device"] / tot["flops"] if tot["flops"] else 0.0
        ),
        "roofline_fraction": (
            # fraction of the chip's peak the dominant resource implies for
            # useful model flops: (model_flops/peak) / step_time_bound
            (mf["model_flops_per_device"] / PEAK_FLOPS) / bound if bound else 0.0
        ),
        "totals_per_device": tot,
        "model_flops": mf,
        "extrapolation": ext,
    }
    if dryrun_record is not None and dryrun_record.get("status") == "ok":
        rec["peak_bytes_full_compile"] = dryrun_record["per_device"]["peak_bytes"]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--attn", default="chunked")
    ap.add_argument("--out", default=ROOFLINE_PATH)
    ap.add_argument("--dryrun-results", default="experiments/dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    try:
        with open(args.dryrun_results) as f:
            dres = json.load(f)
    except (OSError, json.JSONDecodeError):
        dres = {}
    try:
        with open(args.out) as f:
            results = json.load(f)
    except (OSError, json.JSONDecodeError):
        results = {}

    archs = lm_archs() if args.arch == "all" else [args.arch.replace("-", "_")]
    for arch in archs:
        shape_names = list(cells(arch)) if args.shape == "all" else [args.shape]
        for shape_name in shape_names:
            key = f"{arch}|{shape_name}|{args.attn}"
            if key in results and not args.force:
                print(f"[skip] {key}")
                continue
            print(f"[roofline] {key}", flush=True)
            dr = dres.get(f"{arch}|{shape_name}|16x16|{args.attn}")
            try:
                rec = roofline_cell(
                    arch, shape_name, attn_impl=args.attn, dryrun_record=dr
                )
                print(
                    f"  compute={rec['compute_s']*1e3:.2f}ms "
                    f"memory={rec['memory_s']*1e3:.2f}ms "
                    f"collective={rec['collective_s']*1e3:.2f}ms "
                    f"dominant={rec['dominant']} "
                    f"useful={rec['useful_flops_ratio']:.2f} "
                    f"roofline_frac={rec['roofline_fraction']:.3f}"
                )
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape_name, "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
                print(f"  FAILED: {rec['error']}")
            results[key] = rec
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
