import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Performance hillclimbing (EXPERIMENTS.md §Perf).
#
# Runs named experiment variants against a cell, recomputes the three
# roofline terms via the same unrolled-extrapolation pipeline, and appends
# hypothesis -> change -> before/after -> verdict records to
# experiments/perf_log.json.
#
#   PYTHONPATH=src python -m repro.launch.perf --cell stablelm_1_6b/train_4k \
#       --variant pure_dp
import argparse
import json
from typing import Any, Dict, Optional

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.launch import roofline as RL
from repro.launch.dryrun import run_cell

PERF_LOG = "experiments/perf_log.json"


#: named experiment variants: kwargs passed to run_cell (rules = activation
#: rule overrides, param_rules = parameter sharding overrides, ...).
VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    # pure data parallelism: re-purpose the "model" axis as extra FSDP width;
    # no TP -> no per-layer activation all-reduces, weights ZeRO-3 over 256.
    "pure_dp": {
        "rules": {
            "batch": ("data", "model"), "seq_res": None, "heads": None,
            "kv_heads": None, "mlp": None, "vocab": None, "moe_t": None,
            "moe_cap": None, "moe_flat": None,
        },
        "param_rules": {
            "vocab": None, "embed": ("data", "model"), "embed_tp": None,
            "heads": None, "kv_heads": None, "mlp": None,
        },
        "accum_steps": 1,
    },
    # half-TP: model axis split 2-way TP x 8-way extra DP is not expressible
    # on a fixed mesh; instead keep TP but turn off sequence parallelism.
    "no_sp": {"rules": {"seq_res": None}},
    # remat policy: save matmul outputs (no forward recompute in backward)
    "remat_dots": {"remat": "dots"},
    "remat_none": {"remat": "none"},
    # un-fused attention baseline (what you lose without flash)
    "naive_attn": {"attn_impl": "naive"},
    # MoE: tighter capacity
    "cap_1_0": {"cfg_overrides": {"moe_capacity_factor": 1.0}},
    # decode: bf16 KV (undo the fp8 default) for A/B
    "kv_bf16": {"cfg_overrides": {"kv_cache_dtype": "bfloat16"}},
    # decode: fp8 KV cache
    "kv_fp8": {"cfg_overrides": {"kv_cache_dtype": "float8_e4m3fn"}},
    # combos
    "pure_dp_dots": {
        "rules": {
            "batch": ("data", "model"), "seq_res": None, "heads": None,
            "kv_heads": None, "mlp": None, "vocab": None, "moe_t": None,
            "moe_cap": None, "moe_flat": None,
        },
        "param_rules": {
            "vocab": None, "embed": ("data", "model"), "embed_tp": None,
            "heads": None, "kv_heads": None, "mlp": None,
        },
        "accum_steps": 1,
        "remat": "dots",
    },
}


def measure(arch: str, shape_name: str, variant: str) -> Dict[str, Any]:
    kw = dict(VARIANTS[variant])
    cfg = get_config(arch)
    # reuse the roofline extrapolation but with variant kwargs
    base_kw = dict(
        attn_impl=kw.pop("attn_impl", "chunked"),
        scan_layers=False, multi_pod=False,
        accum_steps=kw.pop("accum_steps", 1),
        remat=kw.pop("remat", "full"),
        **kw,
    )

    if cfg.family == "hybrid":
        every = cfg.attn_every
        f6 = RL._pd(run_cell(arch, shape_name, n_layers=every, **base_kw))
        f7 = RL._pd(run_cell(arch, shape_name, n_layers=every + 1, **base_kw))
        f12 = RL._pd(run_cell(arch, shape_name, n_layers=2 * every, **base_kw))
        Bm = {m: f7[m] - f6[m] for m in RL.METRICS}
        Ba = {m: f12[m] - f6[m] - every * Bm[m] for m in RL.METRICS}
        A = {m: f6[m] - every * Bm[m] - Ba[m] for m in RL.METRICS}
        L = cfg.n_layers
        tot = {m: A[m] + L * Bm[m] + (L // every) * Ba[m] for m in RL.METRICS}
    else:
        f1 = RL._pd(run_cell(arch, shape_name, n_layers=1, **base_kw))
        f2 = RL._pd(run_cell(arch, shape_name, n_layers=2, **base_kw))
        co = RL._lin2(f1, f2)
        tot = {m: co["A"][m] + cfg.n_layers * co["B"][m] for m in RL.METRICS}

    mf = RL.model_flops_per_device(arch, shape_name)
    terms = {
        "compute_s": tot["flops"] / RL.PEAK_FLOPS,
        "memory_s": tot["fused_bytes"] / RL.HBM_BW,
        "collective_s": tot["collective_bytes"] / RL.ICI_BW,
    }
    bound = max(terms.values())
    # peak memory check at full scale (scanned compile)
    full = run_cell(arch, shape_name, False, scan_layers=True, **{
        k: v for k, v in base_kw.items()
        if k not in ("scan_layers", "multi_pod")
    })
    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        **terms,
        "dominant": max(terms, key=terms.get),
        "useful_flops_ratio": mf["model_flops_per_device"] / tot["flops"],
        "roofline_fraction": (mf["model_flops_per_device"] / RL.PEAK_FLOPS) / bound,
        "peak_bytes_full": full["per_device"]["peak_bytes"],
        "totals_per_device": tot,
    }


def log_experiment(rec: Dict[str, Any], hypothesis: str = "") -> None:
    try:
        with open(PERF_LOG) as f:
            log = json.load(f)
    except (OSError, json.JSONDecodeError):
        log = []
    rec = dict(rec)
    if hypothesis:
        rec["hypothesis"] = hypothesis
    log.append(rec)
    os.makedirs(os.path.dirname(PERF_LOG) or ".", exist_ok=True)
    with open(PERF_LOG, "w") as f:
        json.dump(log, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch/shape")
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--hypothesis", default="")
    args = ap.parse_args()
    arch, shape_name = args.cell.split("/")
    arch = arch.replace("-", "_")
    rec = measure(arch, shape_name, args.variant)
    log_experiment(rec, args.hypothesis)
    print(json.dumps({k: v for k, v in rec.items() if k != "totals_per_device"},
                     indent=1))


if __name__ == "__main__":
    main()
