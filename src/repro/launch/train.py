"""Training launcher: ``python -m repro.launch.train --arch stablelm_1_6b``.

On this CPU container it trains the reduced (smoke) configs; pass
``--full`` on real hardware to use the production config, mesh, and
sharding rules (same code path the dry-run compiles for 256/512 chips).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_config, lm_archs
from repro.data.pipeline import DataConfig
from repro.dist import sharding as shd
from repro.models.transformer import LM
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1_6b", choices=lm_archs())
    ap.add_argument("--full", action="store_true",
                    help="production config + mesh (real hardware)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke()
    else:
        from repro.launch.mesh import make_production_mesh

        shd.set_mesh(make_production_mesh())

    model = LM(cfg, attn_impl="chunked", remat="full" if args.full else None)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_per_shard=args.batch)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=f"{args.ckpt_dir}/{args.arch}", log_every=10,
        accum_steps=args.accum, grad_compression=args.grad_compression,
    )
    out = Trainer(model, data, ocfg, tcfg).run()
    losses = [m["loss"] for _, m in out["history"]]
    print(f"[train] {args.arch}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps)")


if __name__ == "__main__":
    main()
