"""Checkpoint protocol: atomicity, completeness flag, GC, restore."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
        "b": {"c": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    assert ckpt.latest_step(str(tmp_path)) == 7
    got = ckpt.restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


import jax  # noqa: E402  (used above)


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    # simulate a crash mid-write: manifest exists but incomplete
    d = tmp_path / "step_00000009"
    d.mkdir()
    with open(d / "manifest.json", "w") as f:
        json.dump({"step": 9, "complete": False, "n_leaves": 0, "leaves": []}, f)
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_gc_keeps_latest(tmp_path):
    t = _tree()
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, t, keep=2)
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000004", "step_00000005"]


def test_restore_validates_shapes(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    wrong = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.zeros((3,), jnp.int32)}}
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, wrong)


def test_restore_with_shardings(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"a": NamedSharding(mesh, P()), "b": {"c": NamedSharding(mesh, P())}}
    got = ckpt.restore(str(tmp_path), 1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))
