"""Fault injection + graceful degradation (ISSUE 7): typed error taxonomy,
deterministic injector, blacklist/remap, the RobustAllocator fallback chain,
faulted PUD execution, controller stalls, and the invariant auditors."""
import numpy as np
import pytest

from repro.core.allocators import (
    HUGE_PAGE,
    HugePageModel,
    PhysicalMemory,
)
from repro.core.arena import TilePool
from repro.core.controller import ChannelController, DramController
from repro.core.dram import AddressMap, DramGeometry, BANK_REGION_SCHEME
from repro.core.puma import PumaAllocator, RobustAllocator
from repro.core import pud
from repro.robustness import (
    BasePageExhausted,
    DeadlineExceeded,
    DoubleFree,
    FaultInjector,
    FaultPlan,
    HugePageExhausted,
    InvariantViolation,
    PoolExhausted,
    RequestRejected,
    TranslationError,
    check_allocator,
    check_tile_pool,
)

pytestmark = pytest.mark.chaos

AMAP = AddressMap()
REGION = AMAP.region_bytes
SMALL = AddressMap(DramGeometry(subarrays_per_bank=16))


def fresh(n_huge=16, injector=None, amap=AMAP, **mem_kw):
    mem = PhysicalMemory(amap, n_huge_pages=64, injector=injector, **mem_kw)
    pa = PumaAllocator(mem, injector=injector)
    pa.pim_preallocate(n_huge)
    return pa


# ---------------------------------------------------------------------------
# error taxonomy: typed errors stay compatible with the builtins they replace
# ---------------------------------------------------------------------------

def test_error_taxonomy_builtin_compat():
    assert issubclass(PoolExhausted, MemoryError)
    assert issubclass(HugePageExhausted, MemoryError)
    assert issubclass(BasePageExhausted, MemoryError)
    assert issubclass(TranslationError, ValueError)
    assert issubclass(DoubleFree, KeyError)
    assert issubclass(InvariantViolation, AssertionError)
    assert issubclass(DeadlineExceeded, RequestRejected)


def test_error_context_in_message():
    err = PoolExhausted("PUMA pool exhausted", wanted=7, free=3)
    s = str(err)
    assert "wanted=7" in s and "free=3" in s
    assert err.ctx == {"wanted": 7, "free": 3}


def test_typed_errors_raised_by_allocator():
    pa = fresh(n_huge=1)
    with pytest.raises(PoolExhausted) as ei:
        pa.alloc((pa.free_regions() + 1) * REGION)
    assert isinstance(ei.value, MemoryError)
    a = pa.pim_alloc(REGION)
    pa.pim_free(a)
    with pytest.raises(DoubleFree):
        pa.pim_free(a)
    mem = PhysicalMemory(AMAP, n_huge_pages=2)
    with pytest.raises(HugePageExhausted) as ei:
        mem.take_huge(3)
    assert ei.value.ctx["wanted"] == 3 and not ei.value.injected


# ---------------------------------------------------------------------------
# injector: determinism + rate semantics
# ---------------------------------------------------------------------------

def test_fault_plan_validates_rates():
    with pytest.raises(ValueError):
        FaultPlan(rowclone_fail_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(channel_stall_ns=-1.0)


def test_injector_is_deterministic():
    plan = FaultPlan(seed=7, rowclone_fail_rate=0.3, permanent_fraction=0.5,
                     huge_exhaust_rate=0.2, alloc_miss_rate=0.2,
                     channel_stall_rate=0.2)

    def drive(inj):
        trace = []
        for _ in range(50):
            trace.append(inj.huge_denied())
            trace.append(inj.alloc_missed())
            trace.append(inj.rowclone_faults(list(range(8))).tolist())
            trace.append(inj.stall_ns())
        return trace, inj.stats.as_dict(), sorted(inj.blacklist)

    a = drive(FaultInjector(plan))
    b = drive(FaultInjector(plan))
    assert a == b
    c = drive(FaultInjector(FaultPlan(seed=8, rowclone_fail_rate=0.3,
                                      permanent_fraction=0.5,
                                      huge_exhaust_rate=0.2,
                                      alloc_miss_rate=0.2,
                                      channel_stall_rate=0.2)))
    assert a[0] != c[0]


def test_default_plan_is_noop():
    inj = FaultInjector()
    assert not any(inj.huge_denied() or inj.alloc_missed() for _ in range(100))
    assert not inj.rowclone_faults(list(range(64))).any()
    assert inj.stall_ns() == 0.0
    assert inj.stats.total_injected() == 0


def test_rate_one_always_fires():
    inj = FaultInjector(FaultPlan(huge_exhaust_rate=1.0, alloc_miss_rate=1.0,
                                  rowclone_fail_rate=1.0,
                                  channel_stall_rate=1.0, channel_stall_ns=42.0))
    assert inj.huge_denied() and inj.alloc_missed()
    assert inj.rowclone_faults([0, 1, 2]).all()
    assert inj.stall_ns() == 42.0


# ---------------------------------------------------------------------------
# hook sites: huge-page denial, alloc misses, blacklisted subarrays
# ---------------------------------------------------------------------------

def test_injected_huge_denial_is_transient_and_flagged():
    inj = FaultInjector(FaultPlan(huge_exhaust_rate=1.0))
    mem = PhysicalMemory(AMAP, n_huge_pages=8, injector=inj)
    with pytest.raises(HugePageExhausted) as ei:
        mem.take_huge(2)
    assert ei.value.injected
    assert len(mem.free_huge) == 8          # pool untouched: transient denial
    mem.injector = None
    assert len(mem.take_huge(2)) == 2       # same pool succeeds without faults


def test_injected_alloc_miss_conserves_pool():
    inj = FaultInjector(FaultPlan(alloc_miss_rate=1.0))
    pa = fresh(n_huge=4, injector=inj)
    total = pa.free_regions()
    assert pa.pim_alloc(REGION) is None
    assert pa.free_regions() == total
    assert pa.stats.injected_misses == 1
    check_allocator(pa).assert_ok()


def test_boot_blacklist_quarantines_at_preallocate():
    probe = fresh(n_huge=4)
    a = probe.pim_alloc(REGION)
    dead = AMAP.region_subarray(a.extents[0].pa)

    inj = FaultInjector(FaultPlan(blacklist_subarrays=(dead,)))
    pa = fresh(n_huge=4, injector=inj)
    assert pa.quarantined_regions() > 0
    assert dead in pa.blacklisted_subarrays
    assert dead not in pa.free_counts()
    check_allocator(pa).assert_ok()
    # nothing ever lands there
    for _ in range(8):
        b = pa.pim_alloc(4 * REGION)
        assert b is not None
        sas = AMAP.region_subarrays(np.asarray([e.pa for e in b.extents]))
        assert dead not in sas.tolist()


def test_blacklist_subarray_remaps_live_rows_with_data():
    mem = PhysicalMemory(SMALL, seed=1, n_huge_pages=16, occupancy=0.1)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(8)
    size = 4 * SMALL.region_bytes
    a = pa.pim_alloc(size)
    phys = np.zeros(SMALL.total_bytes, np.uint8)
    data = np.random.default_rng(0).integers(0, 256, size, dtype=np.uint8)
    for e in a.extents:
        phys[e.pa:e.pa + e.nbytes] = data[e.va_off:e.va_off + e.nbytes]

    dead = SMALL.region_subarray(a.extents[0].pa)
    remapped = pa.blacklist_subarray(dead, phys=phys)
    assert remapped >= 1
    assert pa.stats.remapped_regions == remapped
    check_allocator(pa).assert_ok()
    # same VA identity, same bytes, no extent left on the dead subarray
    assert pa.lookup(a.va) is a
    got = np.concatenate([phys[e.pa:e.pa + e.nbytes] for e in a.extents])
    np.testing.assert_array_equal(got[:size], data)
    sas = SMALL.region_subarrays(np.asarray([e.pa for e in a.extents]))
    assert dead not in sas.tolist()
    # aligned allocation against the remapped hint still works
    b = pa.pim_alloc_align(size, a)
    assert b is not None
    check_allocator(pa).assert_ok()


def test_blacklist_remap_raises_when_pool_dry():
    pa = fresh(n_huge=1)
    allocs = []
    while True:
        a = pa.pim_alloc(REGION)
        if a is None:
            break
        allocs.append(a)
    dead = AMAP.region_subarray(allocs[0].extents[0].pa)
    with pytest.raises(PoolExhausted):
        pa.blacklist_subarray(dead)


# ---------------------------------------------------------------------------
# RobustAllocator: bounded retry + fallback chain PUMA -> huge -> base
# ---------------------------------------------------------------------------

def test_fallback_chain_serves_from_puma_first():
    ra = RobustAllocator(fresh(n_huge=8))
    a = ra.alloc(4 * REGION)
    assert ra.tier_of(a) == "puma"
    assert ra.stats.puma == 1 and ra.stats.fallback_fraction() == 0.0
    ra.free(a)
    with pytest.raises(DoubleFree):
        ra.free(a)


def test_fallback_refills_pud_pool_before_degrading():
    pa = fresh(n_huge=1)
    ra = RobustAllocator(pa, refill_huge_pages=4)
    need = pa.free_regions() + 2            # more than the pool holds now
    a = ra.alloc(need * REGION)
    assert ra.tier_of(a) == "puma"          # refill kept it on the PUD tier
    assert ra.stats.refills >= 1 and ra.stats.retries >= 1
    assert ra.stats.backoff_ns > 0
    check_allocator(pa).assert_ok()


def test_fallback_degrades_to_huge_then_base_then_raises():
    amap = AddressMap(DramGeometry(subarrays_per_bank=16))
    mem = PhysicalMemory(amap, n_huge_pages=2, occupancy=0.0)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(1)                   # PUD pool: 1 huge page
    ra = RobustAllocator(pa, refill_huge_pages=4)
    pool_regions = pa.free_regions()

    a = ra.alloc(pool_regions * REGION)     # drains the PUD tier exactly
    assert ra.tier_of(a) == "puma"
    b = ra.alloc(HUGE_PAGE)                 # refill fails (pool dry): tier 2
    assert ra.tier_of(b) == "huge"
    c = ra.alloc(64 * 4096)                 # huge pages gone too: tier 3
    assert ra.tier_of(c) == "base"
    assert ra.stats.fallback_fraction() == pytest.approx(2 / 3)
    for x in (a, b, c):
        ra.free(x)
    d = ra.alloc(HUGE_PAGE)                 # freed regions revive tier 1
    assert ra.tier_of(d) == "puma"
    assert len(mem.free_huge) >= 1          # tier-2 pages went back to the OS


def test_fallback_absorbs_transient_faults():
    pa = fresh(n_huge=8)                    # seed the pool fault-free ...
    inj = FaultInjector(FaultPlan(seed=3, alloc_miss_rate=0.5,
                                  huge_exhaust_rate=0.5))
    pa.injector = pa.mem.injector = inj     # ... then the machine degrades
    ra = RobustAllocator(pa)
    allocs = [ra.alloc(2 * REGION) for _ in range(20)]
    assert ra.stats.served == 20            # every request was served
    assert ra.stats.retries > 0             # ... not on the first try
    assert ra.stats.puma > 0
    for a in allocs:
        ra.free(a)
    check_allocator(pa).assert_ok()


# ---------------------------------------------------------------------------
# PUD execution under RowClone faults
# ---------------------------------------------------------------------------

def _puma_operands(op, size, amap, n_huge=8):
    mem = PhysicalMemory(amap, seed=1, n_huge_pages=16, occupancy=0.1)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(n_huge)
    ops = [pa.pim_alloc(size)]
    while len(ops) < pud.N_OPERANDS[op]:
        ops.append(pa.pim_alloc_align(size, ops[0]))
    return pa, ops


def test_simulate_op_prices_faulted_rows():
    size = 64 * REGION
    _, ops = _puma_operands("copy", size, AMAP)
    clean = pud.simulate_op("copy", ops, AMAP)
    assert clean.pud_fraction == 1.0 and clean.faulted_rows == 0

    inj = FaultInjector(FaultPlan(seed=1, rowclone_fail_rate=1.0))
    faulty = pud.simulate_op("copy", ops, AMAP, injector=inj)
    assert faulty.faulted_rows == 64        # every PUD row faulted
    assert faulty.t_ns > clean.t_ns         # wasted AAPs + CPU retry
    assert faulty.t_ns > faulty.t_cpu_ns    # degraded mode is honestly priced


def test_execute_op_faulted_rows_still_compute_correct_bytes():
    size = 6 * SMALL.region_bytes + 17
    _, ops = _puma_operands("copy", size, SMALL)
    phys = np.zeros(SMALL.total_bytes, np.uint8)
    data = np.random.default_rng(2).integers(0, 256, size, dtype=np.uint8)
    src, dst = ops
    for e in src.extents:
        n = min(e.nbytes, size - e.va_off)
        phys[e.pa:e.pa + n] = data[e.va_off:e.va_off + n]

    inj = FaultInjector(FaultPlan(seed=5, rowclone_fail_rate=0.5))
    plan = pud.execute_op("copy", ops, phys, SMALL, injector=inj)
    assert plan.faulted_rows > 0            # p(no fault in 7 rows) < 1%
    out = np.zeros(size, np.uint8)
    for e in dst.extents:
        n = min(e.nbytes, size - e.va_off)
        out[e.va_off:e.va_off + n] = phys[e.pa:e.pa + n]
    np.testing.assert_array_equal(out, data)   # graceful: bytes are exact


def test_permanent_faults_blacklist_and_quarantine():
    size = 16 * SMALL.region_bytes
    inj = FaultInjector(FaultPlan(seed=2, rowclone_fail_rate=0.5,
                                  permanent_fraction=1.0))
    mem = PhysicalMemory(SMALL, seed=1, n_huge_pages=16, occupancy=0.1)
    pa = PumaAllocator(mem, injector=inj)
    pa.pim_preallocate(8)
    ops = [pa.pim_alloc(size), None]
    ops[1] = pa.pim_alloc_align(size, ops[0])
    phys = np.zeros(SMALL.total_bytes, np.uint8)
    pud.execute_op("copy", ops, phys, SMALL, injector=inj)
    assert inj.stats.permanent_faults > 0

    # next allocation pulls the blacklist and remaps live rows off dead SAs
    a = pa.pim_alloc(REGION)
    assert a is not None
    assert set(pa.blacklisted_subarrays) == inj.blacklist
    check_allocator(pa).assert_ok()
    # a replan now routes dead-subarray rows to the CPU up front
    plan = pud.plan_rows("copy", ops, SMALL, injector=inj)
    dead_rows = inj.blacklisted_mask(
        pud.row_subarray_table(ops[0], SMALL)[:plan.n_rows]
    )
    assert not (np.asarray(plan.in_pud) & dead_rows).any()


# ---------------------------------------------------------------------------
# controller stalls
# ---------------------------------------------------------------------------

def test_channel_stalls_extend_busy_frontier():
    base = ChannelController(0)
    t_clean = base.enqueue_pud(10, 90.0)

    inj = FaultInjector(FaultPlan(channel_stall_rate=1.0, channel_stall_ns=777.0))
    cc = ChannelController(0, injector=inj)
    t_faulty = cc.enqueue_pud(10, 90.0)
    assert t_faulty == pytest.approx(t_clean + 777.0)
    assert cc.stats.injected_stalls == 1
    assert cc.stats.injected_stall_ns == pytest.approx(777.0)


def test_peek_does_not_consume_fault_randomness():
    amap = AddressMap(DramGeometry(channels=4, subarrays_per_bank=4),
                      BANK_REGION_SCHEME)
    inj = FaultInjector(FaultPlan(seed=9, channel_stall_rate=0.5))
    ctrl = DramController(amap, injector=inj)
    sas = np.arange(16, dtype=np.int64)
    before = inj.stats.channel_stalls
    ctrl.peek_pud(sas, 90.0)
    ctrl.peek_pud(sas, 90.0)
    assert inj.stats.channel_stalls == before    # peek is stateless
    ctrl.dispatch_pud(sas, 90.0)
    rep = ctrl.occupancy_report()
    assert sum(rep["injected_stalls"]) == inj.stats.channel_stalls


# ---------------------------------------------------------------------------
# invariant auditors catch corruption
# ---------------------------------------------------------------------------

def test_invariant_checker_passes_clean_state_and_catches_corruption():
    pa = fresh(n_huge=4)
    a = pa.pim_alloc(3 * REGION)
    check_allocator(pa).assert_ok()
    # corrupt: hand the same region out twice (simulated double-allocation)
    pa._regions_of[a.va].append(pa._regions_of[a.va][0])
    rep = check_allocator(pa)
    assert not rep.ok
    with pytest.raises(InvariantViolation):
        rep.assert_ok()


def test_tile_pool_checker_catches_leak():
    pool = TilePool(4, 8)
    h = pool.alloc(3)
    check_tile_pool(pool).assert_ok()
    h.tiles.pop()                           # leak: tile neither free nor owned
    rep = check_tile_pool(pool)
    assert not rep.ok and any("conservation" in v for v in rep.violations)
