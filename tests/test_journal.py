"""Crash-consistent allocator journaling (ISSUE 8): forced replay is
bit-exact, crash truncation is deterministic, snapshots checkpoint the log,
and tampered logs fail loudly."""
import json
import random

import numpy as np
import pytest

from repro.core.allocators import PhysicalMemory
from repro.core.arena import TilePool
from repro.core.dram import AddressMap, DramGeometry
from repro.core.puma import PumaAllocator
from repro.robustness import JournalReplayError, check_allocator, check_tile_pool
from repro.robustness.journal import (
    Journal,
    allocator_digest,
    kv_pool_digest,
    pool_digest,
    replay_allocator,
    replay_kv_pool,
    replay_pool,
    snapshot_allocator,
)

pytestmark = pytest.mark.churn

AMAP = AddressMap(
    DramGeometry(channels=4, subarrays_per_bank=16, rows_per_subarray=32)
)
REGION = AMAP.region_bytes


def _mem():
    return PhysicalMemory(AMAP, seed=7, n_huge_pages=4)


def _churned(journal, cycles=600, seed=42, compactions=True):
    pa = PumaAllocator(_mem(), journal=journal)
    pa.pim_preallocate(4)
    total = pa.free_regions()
    rng = random.Random(seed)
    live = []
    for cycle in range(cycles):
        if live and (pa.free_regions() < total // 8 or rng.random() < 0.45):
            pa.pim_free(live.pop(rng.randrange(len(live))))
        else:
            a = pa.pim_alloc(rng.randint(REGION // 2, 4 * REGION))
            if a is not None:
                live.append(a)
        if compactions and cycle % 200 == 199:
            from repro.robustness.compaction import compact_allocator

            compact_allocator(pa)
    return pa, live


def test_allocator_replay_is_bit_exact():
    j = Journal()
    pa, live = _churned(j)
    # free down to ~50 % so the blacklist remap has spare capacity
    for a in live[len(live) // 2:]:
        pa.pim_free(a)
    del live[len(live) // 2:]
    # a permanent-fault remap lands in the log too
    sa = int(AMAP.region_subarrays(
        np.asarray([live[0].extents[0].pa], np.int64))[0])
    pa.blacklist_subarray(sa)
    replayed = replay_allocator(j, _mem())
    check_allocator(replayed).assert_ok()
    assert allocator_digest(replayed) == allocator_digest(pa)
    # replay restored the same translations, not just the same counters
    for a in live[:8]:
        r = replayed.lookup(a.va)
        assert r is not None and [e.pa for e in r.extents] == [
            e.pa for e in a.extents
        ]


def test_crash_mid_compaction_is_deterministic():
    j = Journal()
    _churned(j)
    n = len(j.events)
    # truncate at several points, including just before/after the last
    # compact event (crash mid-maintenance)
    compact_seqs = [
        i for i, ev in enumerate(j.events) if ev.kind == "compact"
    ]
    cuts = {1, n // 3, n // 2, n - 1}
    if compact_seqs:
        cuts.update({compact_seqs[-1], compact_seqs[-1] + 1})
    for keep in sorted(cuts):
        crash = j.crash_copy(keep)
        r1 = replay_allocator(crash, _mem())
        r2 = replay_allocator(crash, _mem())
        check_allocator(r1).assert_ok()
        assert allocator_digest(r1) == allocator_digest(r2), keep


def test_snapshot_checkpoints_the_log():
    j = Journal()
    pa, _ = _churned(j, cycles=300)
    j.snapshot(snapshot_allocator(pa))
    assert not j.events                 # WAL truncated at the checkpoint
    # post-snapshot traffic replays on top of the installed base
    a = pa.pim_alloc(2 * REGION)
    assert a is not None
    pa.pim_free(a)
    replayed = replay_allocator(j, _mem())
    assert allocator_digest(replayed) == allocator_digest(pa)


def test_journal_json_roundtrip_and_tamper_detection():
    j = Journal()
    pa, _ = _churned(j, cycles=200, compactions=False)
    j2 = Journal.from_json(j.to_json())
    assert allocator_digest(replay_allocator(j2, _mem())) == \
        allocator_digest(pa)
    # tamper with an alloc outcome: forced replay must refuse, not guess
    blob = json.loads(j.to_json())
    for ev in blob["events"]:
        if ev["kind"] == "alloc":
            ev["regions"][0] ^= 0x4                     # bogus region PA
            break
    with pytest.raises(JournalReplayError):
        replay_allocator(Journal.from_json(json.dumps(blob)), _mem())


def test_tile_pool_replay_matches_live():
    j = Journal()
    pool = TilePool(8, 32, "puma", journal=j)
    rng = random.Random(9)
    live = []
    for _ in range(800):
        roll = rng.random()
        if live and roll < 0.40:
            pool.free(live.pop(rng.randrange(len(live))))
        elif live and roll < 0.55:
            pool.extend(rng.choice(live), 1)
        else:
            h = pool.alloc(rng.randint(1, 8))
            if h is not None:
                live.append(h)
    from repro.robustness.compaction import compact_pool

    compact_pool(pool)
    check_tile_pool(pool).assert_ok()
    replayed = replay_pool(j, n_arenas=8, tiles_per_arena=32, policy="puma")
    assert pool_digest(replayed) == pool_digest(pool)


def test_kv_pool_replay_matches_live():
    from repro.core.kv_pool import KVPoolConfig, PagedKVPool

    cfg = KVPoolConfig(num_blocks=64, block_size=4, kv_heads=2, head_dim=8,
                       n_layers=1, max_seqs=8, max_blocks_per_seq=16,
                       blocks_per_arena=16, policy="puma", dtype="float32")
    j = Journal()
    kv = PagedKVPool(cfg, journal=j)
    rng = random.Random(13)
    remaining = {}
    for _ in range(500):
        if (not remaining) or (rng.random() < 0.15 and kv._free_slots):
            slot = kv.admit(rng.randint(2, 30))
            if slot is not None:
                remaining[slot] = rng.randint(1, 40)
        else:
            slot = rng.choice(sorted(remaining))
            if rng.random() < 0.05:
                forked = kv.fork(slot, copy_data=False)
                if forked is not None:
                    remaining[forked] = remaining[slot]
            if kv.append_token(slot):
                remaining[slot] -= 1
            else:
                remaining[slot] = 0
            if remaining[slot] <= 0:
                del remaining[slot]
                kv.release(slot)
    kv.compact()
    replayed = replay_kv_pool(j, cfg)
    assert kv_pool_digest(replayed) == kv_pool_digest(kv)
