"""Compaction engine (ISSUE 8): planning, pricing, bit-exact execution,
and the serving engine's watermark maintenance hook."""
import random

import numpy as np
import pytest

from repro.core.allocators import PhysicalMemory
from repro.core.arena import TilePool
from repro.core.dram import AddressMap, DramGeometry
from repro.core.puma import PumaAllocator
from repro.robustness import (
    JournalReplayError,
    check_allocator,
    check_kv_pool,
    check_tile_pool,
)
from repro.robustness.compaction import (
    compact_allocator,
    compact_pool,
    plan_allocator_compaction,
    plan_pool_compaction,
)

pytestmark = pytest.mark.churn


def hyp_seeds(func):
    """Hypothesis-driven seeds when installed, fixed seeds otherwise."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        return pytest.mark.parametrize("seed", [0xC0FFEE, 0xBADF00D])(func)
    return settings(max_examples=2, deadline=None)(
        given(seed=st.integers(0, 2**32 - 1))(func)
    )


AMAP = AddressMap(
    DramGeometry(channels=4, subarrays_per_bank=16, rows_per_subarray=32)
)
REGION = AMAP.region_bytes


def _aged_allocator(seed, cycles=1500, journal=None, phys=None):
    """Churn a small PUD pool to ~90 % utilization; optionally shadow the
    bytes so compaction's data movement can be audited."""
    mem = PhysicalMemory(AMAP, seed=7, n_huge_pages=4)
    pa = PumaAllocator(mem, journal=journal)
    pa.pim_preallocate(4)
    total = pa.free_regions()
    rng = random.Random(seed)
    data_rng = np.random.default_rng(seed)
    expected = {}

    def fill(a):
        if phys is None:
            return
        n = sum(e.nbytes for e in a.extents)
        data = data_rng.integers(0, 256, n, dtype=np.uint8)
        for e in a.extents:
            phys[e.pa:e.pa + e.nbytes] = data[e.va_off:e.va_off + e.nbytes]
        expected[a.va] = data

    live = []
    for _ in range(cycles):
        if live and (pa.free_regions() < total // 10 or rng.random() < 0.45):
            victim = live.pop(rng.randrange(len(live)))
            expected.pop(victim.va, None)
            pa.pim_free(victim)
        else:
            a = pa.pim_alloc(rng.randint(REGION // 2, 4 * REGION))
            if a is not None:
                live.append(a)
                fill(a)
                b = pa.pim_alloc_align(a.size, a)
                if b is not None:
                    live.append(b)
                    fill(b)
    return pa, live, expected


def _read_back(phys, a):
    return np.concatenate([
        phys[e.pa:e.pa + e.nbytes]
        for e in sorted(a.extents, key=lambda e: e.va_off)
    ])


@hyp_seeds
def test_allocator_compaction_concentrates_and_is_bit_exact(seed):
    phys = np.zeros(AMAP.total_bytes, np.uint8)
    pa, live, expected = _aged_allocator(seed, phys=phys)
    frag_before = pa.fragmentation()
    rep = compact_allocator(pa, phys=phys)
    check_allocator(pa).assert_ok()
    if rep.executed:
        assert rep.frag_after < frag_before
        assert rep.cost is not None and rep.cost.total_ns > 0
        # allocator-level moves always cross subarrays: CPU-priced
        assert rep.rowclone_rows == 0 and rep.cpu_rows == rep.executed
    for a in live:
        assert np.array_equal(_read_back(phys, a), expected[a.va]), hex(a.va)
    # translation still agrees with the extents after the remap
    for a in live[:8]:
        assert a.pa_of(0) == a.extents[0].pa


@hyp_seeds
def test_allocator_compaction_idempotent_and_conserves(seed):
    """Repeated blacklist remaps + compaction passes keep conservation
    (preallocated == free + in_use + quarantined, audited by
    check_allocator) and converge: a second pass over an already-compacted
    pool plans nothing new."""
    pa, live, _ = _aged_allocator(seed)
    # free down to ~50 % so the blacklist remap has spare capacity
    for a in live[len(live) // 2:]:
        pa.pim_free(a)
    del live[len(live) // 2:]
    # one permanent-fault remap in the mix, applied twice: the second
    # application must be a no-op (the subarray is already drained)
    sa = int(AMAP.region_subarrays(
        np.asarray([live[0].extents[0].pa], np.int64))[0])
    pa.blacklist_subarray(sa)
    check_allocator(pa).assert_ok()
    assert pa.blacklist_subarray(sa) == 0      # idempotent
    check_allocator(pa).assert_ok()

    rep1 = compact_allocator(pa)
    check_allocator(pa).assert_ok()
    rep2 = compact_allocator(pa)
    check_allocator(pa).assert_ok()
    assert rep2.frag_after <= rep1.frag_after + 1e-9
    # convergence: once free capacity is concentrated, replanning is empty
    rep3 = compact_allocator(pa)
    assert rep3.executed == 0 or rep3.frag_after <= rep2.frag_after
    for a in live:
        pa.pim_free(a)
    check_allocator(pa).assert_ok()


def test_allocator_stale_plan_raises():
    pa, live, _ = _aged_allocator(0xBEEF)
    plan = plan_allocator_compaction(pa)
    if not plan.moves:
        pytest.skip("churn produced an unfragmented pool")
    # consume the plan's destination region behind its back
    dst = plan.moves[0].dst
    sa = int(AMAP.region_subarrays(np.asarray([dst], np.int64))[0])
    assert pa._ordered.take_specific(sa, dst)
    with pytest.raises(JournalReplayError):
        compact_allocator(pa, plan)


def test_pool_run_repair_is_rowclone_priced():
    pool = TilePool(1, 16, "puma")     # one arena: collisions guaranteed
    a = pool.alloc(2)
    b = pool.alloc(2)          # occupies the slots right after a
    pool.extend(a, 2)          # a's tiles fracture around b
    assert a.contiguous_run_fraction() < 1.0
    pool.free(b)               # the gap is free: run repair can re-knit it
    plan = plan_pool_compaction(pool)
    assert plan.rowclone_moves, "expected intra-arena run-repair moves"
    before = a.contiguous_run_fraction()
    rep = compact_pool(pool, plan)
    check_tile_pool(pool).assert_ok()
    assert a.contiguous_run_fraction() >= before
    assert rep.rowclone_rows == len(plan.rowclone_moves)


@hyp_seeds
def test_pool_compaction_under_churn(seed):
    pool = TilePool(8, 32, "puma")
    rng = random.Random(seed)
    live = []
    for _ in range(2000):
        roll = rng.random()
        if live and roll < 0.40:
            pool.free(live.pop(rng.randrange(len(live))))
        elif live and roll < 0.55:
            pool.extend(rng.choice(live), 1)
        else:
            h = pool.alloc(rng.randint(1, 8))
            if h is not None:
                live.append(h)
    owned_before = sorted(
        (h.hid, len(h.tiles)) for h in live
    )
    contig_before = float(np.mean(
        [h.contiguous_run_fraction() for h in live]
    )) if live else 1.0
    rep = compact_pool(pool)
    check_tile_pool(pool).assert_ok()
    assert sorted((h.hid, len(h.tiles)) for h in live) == owned_before
    if rep.executed:
        contig_after = float(np.mean(
            [h.contiguous_run_fraction() for h in live]
        ))
        assert contig_after >= contig_before - 1e-9
    # repeated passes stay safe and never give back handle contiguity
    # (run repair may trade free-run fragmentation for it, so the frag
    # metric alone is not monotone)
    compact_pool(pool)
    check_tile_pool(pool).assert_ok()
    assert sorted((h.hid, len(h.tiles)) for h in live) == owned_before
    if live:
        assert float(np.mean(
            [h.contiguous_run_fraction() for h in live]
        )) >= contig_before - 1e-9


def test_kv_compact_moves_data_bit_exactly():
    import jax.numpy as jnp

    from repro.core.kv_pool import KVPoolConfig, PagedKVPool

    cfg = KVPoolConfig(num_blocks=64, block_size=4, kv_heads=2, head_dim=8,
                       n_layers=2, max_seqs=16, max_blocks_per_seq=16,
                       blocks_per_arena=16, policy="puma", dtype="float32")
    kv = PagedKVPool(cfg)
    rng = np.random.default_rng(11)
    slots = [kv.admit(int(rng.integers(3, 13))) for _ in range(10)]
    for s in slots[::2]:
        kv.release(s)
    slots = slots[1::2] + [kv.admit(int(rng.integers(8, 20))) for _ in range(3)]
    slots = [s for s in slots if s is not None]
    # stamp every live block through the *layer-folded* index space
    tags = {}
    for s in slots:
        h, _ = kv._seqs[s]
        tg = rng.standard_normal(len(h.tiles)).astype(np.float32)
        tags[s] = tg
        for li in range(cfg.n_layers):
            kv.k = kv.k.at[li, jnp.asarray(h.tiles), 0, 0, 0].set(
                jnp.asarray(tg * (li + 1))
            )
    rep = kv.compact(max_moves=64)
    check_kv_pool(kv).assert_ok()
    if rep is None:
        pytest.skip("nothing to compact")
    for s in slots:
        h, _ = kv._seqs[s]
        for li in range(cfg.n_layers):
            got = np.asarray(kv.k[li, jnp.asarray(h.tiles), 0, 0, 0])
            assert np.allclose(got, tags[s] * (li + 1)), (s, li)


def test_engine_maintenance_hook_fires_and_preserves_output():
    import jax

    from repro.configs.registry import get_config
    from repro.core.kv_pool import KVPoolConfig
    from repro.models.transformer import LM
    from repro.serve.engine import MaintenanceConfig, Request, ServeEngine

    cfg = get_config("stablelm_1_6b").smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))

    def pool_cfg():
        return KVPoolConfig(
            num_blocks=64, block_size=8, kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, n_layers=cfg.n_layers, max_seqs=8,
            max_blocks_per_seq=16, blocks_per_arena=16, policy="puma",
            dtype="float32",
        )

    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(0, cfg.vocab_size, 9)) for _ in range(4)]

    def drive(maint):
        eng = ServeEngine(model, params, pool_cfg(), use_kernel=False,
                          maintenance=maint)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=list(p), max_new=6))
        done = eng.run()
        return eng, {r.rid: r.out for r in done}

    _, base_out = drive(None)
    eng, out = drive(MaintenanceConfig(
        free_low=0.9, frag_high=0.05, contig_low=0.999,
        max_moves=64, every=2,
    ))
    m = eng.metrics()
    assert m["compaction_passes"] > 0
    assert m["blocks_migrated"] > 0
    assert m["maintenance_ns"] > 0
    assert out == base_out          # compaction never changes generation
    # the rate limiter actually limits
    assert eng.compaction_passes <= eng.clock // 2 + 1
