"""Traffic-generator unit tests (ISSUE 9): all model-free and fast.

The serving benchmark's credibility rests on the streams being exactly
reproducible from their seeds, so most of these tests are determinism and
shape checks on :mod:`repro.serve.loadgen` — no JAX, no engine.
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serve.loadgen import (
    SCENARIO_NAMES,
    ArrivalSpec,
    RequestSpec,
    SimCost,
    TenantSpec,
    build_scenario,
    tenant_from_arch,
)

KINDS = ("steady", "poisson", "bursty")


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
def test_arrivals_are_monotone_integer_ticks(kind):
    spec = ArrivalSpec(kind)
    ticks = spec.arrivals(np.random.default_rng(7), 200)
    assert len(ticks) == 200
    assert all(isinstance(t, int) for t in ticks)
    assert ticks[0] >= 0
    assert all(b >= a for a, b in zip(ticks, ticks[1:]))


@pytest.mark.parametrize("kind", KINDS)
def test_arrivals_deterministic_in_seed(kind):
    spec = ArrivalSpec(kind)
    a = spec.arrivals(np.random.default_rng(123), 100)
    b = spec.arrivals(np.random.default_rng(123), 100)
    c = spec.arrivals(np.random.default_rng(124), 100)
    assert a == b
    if kind != "steady":          # steady is seed-independent by design
        assert a != c


def test_steady_arrivals_closed_form():
    spec = ArrivalSpec("steady", rate=0.5)
    assert spec.arrivals(np.random.default_rng(0), 6) == [0, 2, 4, 6, 8, 10]


def test_bursty_arrivals_cluster():
    spec = ArrivalSpec("bursty", burst_size=8, burst_gap=24.0)
    ticks = spec.arrivals(np.random.default_rng(902), 32)
    # full bursts land on a single tick, and gaps separate the clusters
    assert ticks[:8] == [ticks[0]] * 8
    assert ticks[8] > ticks[7]
    assert len(set(ticks)) == 4   # 32 requests / burst_size 8


def test_empty_and_unknown_arrivals():
    assert ArrivalSpec("poisson").arrivals(np.random.default_rng(0), 0) == []
    with pytest.raises(ValueError, match="unknown arrival kind"):
        ArrivalSpec("zipf").arrivals(np.random.default_rng(0), 4)


# ---------------------------------------------------------------------------
# tenants
# ---------------------------------------------------------------------------

def test_tenant_from_arch_is_deterministic_and_capped():
    a = tenant_from_arch("stablelm_1_6b", cap_tokens=40)
    b = tenant_from_arch("stablelm_1_6b", cap_tokens=40)
    assert a == b
    assert all(p <= 40 for p in a.prompt_lens)
    assert a.prompt_lens == tuple(sorted(a.prompt_lens))


def test_tenant_from_arch_monotone_in_model_scale():
    small = tenant_from_arch("stablelm_1_6b", cap_tokens=512)
    big = tenant_from_arch("granite_34b", cap_tokens=512)
    assert max(big.prompt_lens) > max(small.prompt_lens)
    assert max(big.max_new_lens) >= max(small.max_new_lens)


def test_request_spec_round_trips_into_engine_request():
    spec = RequestSpec(rid=9, arrive_step=3, tenant="t", prompt=(1, 2, 3),
                       max_new=4, deadline_steps=20, cancel_after=2)
    req = spec.to_request()
    assert (req.rid, req.prompt, req.max_new) == (9, [1, 2, 3], 4)
    assert req.deadline_steps == 20
    assert req.tenant == "t"


# ---------------------------------------------------------------------------
# scenario registry
# ---------------------------------------------------------------------------

def test_registry_covers_the_five_scenarios():
    assert len(SCENARIO_NAMES) == 5
    seeds = set()
    for name in SCENARIO_NAMES:
        sc = build_scenario(name, smoke=True)
        assert sc.name == name
        seeds.add(sc.seed)
        assert sc.pool_overrides() == dict(sc.pool)
    assert len(seeds) == 5        # every scenario owns its seed
    with pytest.raises(ValueError, match="unknown scenario"):
        build_scenario("nope")


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_generated_streams_are_reproducible(name):
    sc = build_scenario(name, smoke=True)
    assert sc.generate() == sc.generate()
    assert all(
        b.arrive_step >= a.arrive_step
        for a, b in zip(sc.generate(), sc.generate()[1:])
    )


def test_smoke_shrinks_requests_but_keeps_the_seed():
    full = build_scenario("bursty")
    smoke = build_scenario("bursty", smoke=True)
    assert full.seed == smoke.seed
    assert full.n_requests > smoke.n_requests
    # the smaller stream is a prefix-compatible draw: same tenants, same pools
    assert full.tenants == smoke.tenants
    assert full.pool == smoke.pool


def test_multi_tenant_mix_draws_every_registry_tenant():
    sc = build_scenario("multi_tenant", smoke=False)
    specs = sc.generate()
    by_tenant = {t.name: 0 for t in sc.tenants}
    for s in specs:
        by_tenant[s.tenant] += 1
    assert all(v > 0 for v in by_tenant.values())
    # weights 3:2:1 show up in the draw ordering
    assert by_tenant["stablelm_1_6b"] > by_tenant["granite_34b"]


def test_cancel_heavy_stream_carries_cancel_and_deadline_fields():
    sc = build_scenario("cancel_heavy", smoke=False)
    specs = sc.generate()
    impatient = [s for s in specs if s.tenant == "impatient"]
    deadline = [s for s in specs if s.tenant == "deadline"]
    cancels = [s.cancel_after for s in impatient if s.cancel_after is not None]
    assert cancels and all(1 <= c <= 4 for c in cancels)
    frac = len(cancels) / len(impatient)
    assert 0.25 < frac < 0.65     # ~45% cancel rate
    assert deadline and all(s.deadline_steps == 6 for s in deadline)
    assert all(s.cancel_after is None for s in deadline)


def test_prompt_lengths_come_from_the_tenant_buckets():
    for name in SCENARIO_NAMES:
        sc = build_scenario(name, smoke=True)
        buckets = {t.name: set(t.prompt_lens) for t in sc.tenants}
        for s in sc.generate():
            assert len(s.prompt) in buckets[s.tenant], (name, s.rid)


# ---------------------------------------------------------------------------
# deterministic serving-time model
# ---------------------------------------------------------------------------

def test_simcost_is_linear_in_the_engine_counters():
    cost = SimCost(step_overhead_ns=10.0, decode_token_ns=2.0,
                   prefill_token_ns=1.0)
    eng = SimpleNamespace(clock=5, tokens_decoded=7, tokens_prefilled=11,
                          maintenance_ns=13.0)
    assert cost.total_ns(eng) == 10.0 * 5 + 2.0 * 7 + 1.0 * 11 + 13.0
    assert dataclasses.asdict(SimCost()) == {
        "step_overhead_ns": 2_000.0,
        "decode_token_ns": 500.0,
        "prefill_token_ns": 150.0,
    }
