"""decay_attention Pallas kernel vs the sequential oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decay_attention import ops
from repro.models.linear_scan import decay_attention_ref


@pytest.mark.parametrize(
    "B,S,H,dk,dv,use_bonus",
    [
        (2, 64, 2, 16, 16, False),   # mamba-style (scalar-ish decay ok too)
        (1, 100, 3, 32, 32, True),   # rwkv-style with bonus, ragged S
        (2, 32, 1, 8, 24, True),     # dk != dv
        (1, 33, 2, 64, 64, False),   # one chunk + remainder
    ],
)
def test_kernel_matches_oracle(B, S, H, dk, dv, use_bonus):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
    lw = jnp.asarray(-np.abs(rng.normal(size=(B, S, H, dk))) * 0.3, jnp.float32)
    bonus = (
        jnp.asarray(rng.normal(size=(H, dk)) * 0.2, jnp.float32)
        if use_bonus else None
    )
    got = ops.decay_attention(q, k, v, lw, bonus=bonus, use_kernel=True)
    want = decay_attention_ref(q, k, v, lw, bonus=bonus)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 2e-3, err


def test_kernel_chunk_boundary_state_carry():
    """Exactly 3 chunks: the VMEM state must persist across grid steps."""
    rng = np.random.default_rng(1)
    B, S, H, d = 1, 96, 1, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, d)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    lw = jnp.full((B, S, H, d), -0.05, jnp.float32)
    got = ops.decay_attention(q, k, v, lw, use_kernel=True)
    want = decay_attention_ref(q, k, v, lw)
    # last chunk depends on the carried state from the first two
    err = float(jnp.max(jnp.abs(got[:, -32:] - want[:, -32:])))
    assert err < 2e-3, err
