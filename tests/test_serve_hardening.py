"""Hardened serving path (ISSUE 7): loud rejection of never-admissible
requests, bounded-lookahead admission (head-of-line fix), deadlines, and
LRU preemption with bit-exact recompute-on-resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.kv_pool import KVPoolConfig
from repro.models.transformer import LM
from repro.robustness import (
    DeadlineExceeded,
    FaultInjector,
    FaultPlan,
    RequestRejected,
    check_engine,
)
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm_1_6b").smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    return model, params


def _pool_cfg(cfg, **kw):
    base = dict(
        num_blocks=16, block_size=8, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        n_layers=cfg.n_layers, max_seqs=2, max_blocks_per_seq=16,
        blocks_per_arena=16, policy="puma", dtype="float32",
    )
    base.update(kw)
    return KVPoolConfig(**base)


def _dense_generate(model, params, prompt, max_new):
    toks = jnp.asarray([prompt], jnp.int32)
    S = len(prompt)
    cache = model.init_cache(1, S + max_new + 1)
    batch = {"tokens": toks, "positions": jnp.arange(S, dtype=jnp.int32)[None]}
    logits, cache = model.decode_step(params, batch, cache)
    out = [int(jnp.argmax(logits[0]))]
    for t in range(max_new - 1):
        batch = {
            "tokens": jnp.asarray([[out[-1]]], jnp.int32),
            "positions": jnp.asarray([[S + t]], jnp.int32),
        }
        logits, cache = model.decode_step(params, batch, cache)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_never_admissible_request_rejected_at_submit(model_and_params):
    model, params = model_and_params
    eng = ServeEngine(model, params, _pool_cfg(model.cfg), use_kernel=False)
    # capacity: min(16, 16) blocks * 8 tokens = 128 tokens; ask for more
    with pytest.raises(RequestRejected) as ei:
        eng.submit(Request(rid=0, prompt=list(range(120)), max_new=20))
    assert ei.value.ctx["blocks_needed"] > eng.pool.capacity_blocks
    with pytest.raises(RequestRejected):
        eng.submit(Request(rid=1, prompt=[], max_new=4))
    # loudly recorded, not silently dropped
    assert [r.rid for r in eng.rejected] == [0, 1]
    assert all(r.error is not None for r in eng.rejected)
    assert not eng.queue
    check_engine(eng).assert_ok()


def test_stalled_queue_is_rejected_with_report(model_and_params):
    model, params = model_and_params
    inj = FaultInjector(FaultPlan(alloc_miss_rate=1.0))   # admission never works
    eng = ServeEngine(model, params, _pool_cfg(model.cfg), use_kernel=False,
                      injector=inj, stall_patience=2)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=2))
    with pytest.raises(RequestRejected) as ei:
        eng.run(max_steps=20)
    report = ei.value.ctx["report"]
    assert report["free_tiles"] == report["total_tiles"]  # pool idle yet stuck
    assert eng.rejected[0].status == "rejected"
    assert not eng.queue and not eng.live                 # zero silent drops
    check_engine(eng).assert_ok()
    # the loud path is also visible without raising
    done = ServeEngine(model, params, _pool_cfg(model.cfg), use_kernel=False,
                       injector=FaultInjector(FaultPlan(alloc_miss_rate=1.0)),
                       stall_patience=2)
    done.submit(Request(rid=0, prompt=[1, 2, 3], max_new=2))
    assert done.run(max_steps=20, raise_on_error=False) == []
    assert len(done.rejected) == 1


def test_lookahead_admission_fixes_head_of_line_blocking(model_and_params):
    model, params = model_and_params
    eng = ServeEngine(model, params, _pool_cfg(model.cfg, max_seqs=2),
                      use_kernel=False)
    rng = np.random.default_rng(2)
    big_prompt = list(rng.integers(0, 64, 90))     # 12 blocks: blocked early
    small_prompt = list(rng.integers(0, 64, 8))    # 1 block: always fits
    eng.submit(Request(rid=0, prompt=list(rng.integers(0, 64, 40)), max_new=4))
    eng.submit(Request(rid=1, prompt=big_prompt, max_new=2))
    eng.submit(Request(rid=2, prompt=small_prompt, max_new=4))
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2]    # nobody starves
    by_rid = {r.rid: r for r in done}
    # the small request jumped the blocked big one (bounded lookahead)
    assert by_rid[2].admit_clock < by_rid[1].admit_clock
    check_engine(eng).assert_ok()
    assert eng.pool.pool.free_tiles() == eng.pool.pool.total_tiles


def test_deadline_cancels_queued_request(model_and_params):
    model, params = model_and_params
    eng = ServeEngine(model, params, _pool_cfg(model.cfg, max_seqs=1),
                      use_kernel=False)
    eng.submit(Request(rid=0, prompt=[1, 2, 3, 4], max_new=8))
    eng.submit(Request(rid=1, prompt=[5, 6, 7, 8], max_new=4,
                       deadline_steps=2))       # expires while queued
    done = eng.run()                            # cancellation does not raise
    assert [r.rid for r in done] == [0]
    assert len(eng.cancelled) == 1
    victim = eng.cancelled[0]
    assert victim.rid == 1 and victim.status == "cancelled"
    assert isinstance(victim.error, DeadlineExceeded)
    check_engine(eng).assert_ok()


def test_preemption_resumes_with_bit_exact_recompute(model_and_params):
    model, params = model_and_params
    cfg = model.cfg
    # 8 blocks of 4 tokens: two growing sequences must collide mid-decode
    eng = ServeEngine(
        model, params,
        _pool_cfg(cfg, num_blocks=8, block_size=4, blocks_per_arena=8,
                  max_seqs=2, max_blocks_per_seq=8),
        use_kernel=False,
    )
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, 64, 10)) for _ in range(2)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=10))
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1]
    assert eng.preemptions >= 1                 # the collision happened
    assert max(r.preemptions for r in done) >= 1
    for req in done:
        ref = _dense_generate(model, params, prompts[req.rid], 10)
        assert req.out == ref, (req.rid, req.preemptions)
    check_engine(eng).assert_ok()
    assert eng.pool.pool.free_tiles() == eng.pool.pool.total_tiles


def hyp_seeds(func):
    """Drive ``func(..., seed=...)`` with hypothesis when installed; fall
    back to fixed seeds otherwise (same contract as the churn suite)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        return pytest.mark.parametrize("seed", [0xC0FFEE, 0xBADF00D])(func)
    return settings(max_examples=2, deadline=None)(
        given(seed=st.integers(0, 2**32 - 1))(func)
    )


@hyp_seeds
def test_contended_run_matches_uncontended_bit_exactly(model_and_params, seed):
    """Property (ISSUE 9 satellite): whatever preemption/recompute churn a
    starved pool inflicts, every request decodes the exact tokens it would
    have produced alone on a roomy pool — placement is invisible to the
    math."""
    model, params = model_and_params
    cfg = model.cfg
    rng = np.random.default_rng(seed)
    reqs = [
        (int(rng.integers(8, 13)), list(rng.integers(0, 64, int(n))))
        for n in rng.integers(8, 13, size=3)
        for _ in [0]
    ]
    reqs = [(len(p), p) for _, p in reqs]

    def run(pool_kw):
        eng = ServeEngine(
            model, params, _pool_cfg(cfg, **pool_kw), use_kernel=False,
        )
        for i, (_, p) in enumerate(reqs):
            eng.submit(Request(rid=i, prompt=list(p), max_new=10))
        done = eng.run()
        check_engine(eng).assert_ok()
        assert eng.pool.pool.free_tiles() == eng.pool.pool.total_tiles
        return eng, {r.rid: list(r.out) for r in done}

    # starved: 8 blocks x 4 tokens; any two live seqs (>=18 tokens each by
    # construction) overflow the pool mid-decode, forcing preempt+recompute
    contended, out_c = run(dict(num_blocks=8, block_size=4, max_seqs=2,
                                blocks_per_arena=8, max_blocks_per_seq=8))
    # roomy: 4x the blocks, every sequence fits untouched
    uncontended, out_u = run(dict(num_blocks=32, block_size=4, max_seqs=4,
                                  blocks_per_arena=8, max_blocks_per_seq=8))
    assert contended.preemptions >= 1
    assert uncontended.preemptions == 0
    assert set(out_c) == set(out_u) == {0, 1, 2}
    assert out_c == out_u


def test_step_hooks_get_isolated_snapshots(model_and_params):
    """Regression (ISSUE 10 satellite): each step hook gets its own copy of
    the step sample, and hooks registered/removed from inside a hook do not
    perturb the current iteration — a maintenance consumer that mutates its
    sample (as the watermark bookkeeping does) must not leak an
    inconsistent read into a sampler running in the same tick."""
    model, params = model_and_params
    eng = ServeEngine(model, params, _pool_cfg(model.cfg), use_kernel=False)

    seen_by_b = []

    def hook_a(e, sample):
        # hostile consumer: clobbers every field, then empties its dict,
        # and deregisters itself mid-iteration
        for k in list(sample):
            sample[k] = -1.0
        sample.clear()
        if hook_a in e.step_hooks:
            e.step_hooks.remove(hook_a)

    def hook_b(e, sample):
        seen_by_b.append(dict(sample))

    eng.step_hooks.append(hook_a)
    eng.step_hooks.append(hook_b)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))
    eng.run()
    check_engine(eng).assert_ok()

    assert seen_by_b, "second hook never ran"
    for sample in seen_by_b:
        # pristine values despite hook_a's clobbering in the same tick
        assert sample, "hook saw an emptied sample"
        assert all(v >= 0 for v in sample.values()), sample
        assert 0.0 <= sample["used_fraction"] <= 1.0
    # hook_a removed itself after the first step without skipping hook_b
    assert hook_a not in eng.step_hooks
    assert len(seen_by_b) == eng.clock
