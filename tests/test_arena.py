"""TilePool (TPU arena allocator) invariants + policy quality."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.arena import TilePool


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(1, 40), st.booleans()),
        min_size=1, max_size=30,
    ),
    st.sampled_from(["puma", "first_fit", "random"]),
    st.randoms(use_true_random=False),
)
def test_no_tile_double_booked(ops, policy, rnd):
    pool = TilePool(8, 32, policy=policy)
    live = []
    for n, do_free in ops:
        if do_free and live:
            pool.free(live.pop(rnd.randrange(len(live))))
        else:
            h = pool.alloc(n)
            if h is not None:
                live.append(h)
        tiles = [t for h in live for t in h.tiles]
        assert len(tiles) == len(set(tiles)), "tile double-booked"
        assert all(0 <= t < pool.total_tiles for t in tiles)
        assert pool.free_tiles() + len(tiles) == pool.total_tiles


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 16))
def test_alloc_align_mirrors_arenas_when_space(n1, n2):
    # both fit in half an arena -> the hinted arena always has room, so
    # alignment must be exact (paper §2 "Aligned Allocation" steps 2-3)
    pool = TilePool(8, 32, policy="puma")
    a = pool.alloc(n1)
    b = pool.alloc_align(n2, a)
    arena = lambda t: t // pool.tiles_per_arena
    for k in range(min(n1, n2)):
        assert arena(a.tiles[k]) == arena(b.tiles[k])
    assert pool.stats.align_misses == 0


@settings(max_examples=30, deadline=None)
@given(st.integers(17, 32), st.integers(17, 32))
def test_alloc_align_falls_back_worst_fit(n1, n2):
    # hint consumes >half its arena: the overflow of the aligned allocation
    # must fall back to worst-fit (misses recorded), never fail
    pool = TilePool(8, 32, policy="puma")
    a = pool.alloc(n1)
    b = pool.alloc_align(n2, a)
    assert b is not None and len(b.tiles) == n2
    hits, misses = pool.stats.align_hits, pool.stats.align_misses
    assert hits + misses >= n2
    assert misses >= max(0, n1 + n2 - pool.tiles_per_arena) - (n2 - min(n1, n2))


def test_extend_prefers_adjacent_slot():
    pool = TilePool(4, 64, policy="puma")
    h = pool.alloc(5)
    assert pool.extend(h, 3)
    assert h.contiguous_run_fraction() == 1.0


def test_align_fails_for_dead_hint():
    pool = TilePool(4, 16, policy="puma")
    h = pool.alloc(4)
    pool.free(h)
    assert pool.alloc_align(4, h) is None


def test_puma_beats_baselines_under_churn():
    def run(policy):
        pool = TilePool(16, 64, policy=policy, seed=0)
        rng = np.random.default_rng(0)
        live = []
        fr = []
        for step in range(300):
            if live and rng.random() < 0.4:
                pool.free(live.pop(rng.integers(len(live))))
            h = pool.alloc(int(rng.integers(2, 24)))
            if h is not None:
                live.append(h)
            for h in live:
                if rng.random() < 0.5:
                    pool.extend(h, 1)
        return float(np.mean([h.contiguous_run_fraction() for h in live]))

    puma = run("puma")
    ff = run("first_fit")
    rnd = run("random")
    assert puma > ff and puma > rnd, (puma, ff, rnd)


def test_exhaustion_returns_none():
    pool = TilePool(2, 4, policy="puma")
    assert pool.alloc(9) is None
    assert pool.alloc(8) is not None
    assert pool.alloc(1) is None
