"""Chunked decay linear attention vs sequential oracle (hypothesis sweeps)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.linear_scan import (
    chunked_decay_attention,
    decay_attention_ref,
    decay_attention_step,
)

RNG = np.random.default_rng(0)


def _mk(B, S, H, dk, dv, decay_scale=0.3, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
    lw = jnp.asarray(-np.abs(rng.normal(size=(B, S, H, dk))) * decay_scale, jnp.float32)
    return q, k, v, lw


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 3), st.integers(1, 80), st.integers(1, 3),
    st.integers(1, 24), st.integers(1, 24), st.booleans(), st.integers(0, 99),
)
def test_chunked_matches_sequential(B, S, H, dk, dv, use_bonus, seed):
    q, k, v, lw = _mk(B, S, H, dk, dv, seed=seed)
    bonus = (
        jnp.asarray(np.random.default_rng(seed).normal(size=(H, dk)) * 0.2, jnp.float32)
        if use_bonus else None
    )
    yc, Sc = chunked_decay_attention(q, k, v, lw, bonus=bonus, return_state=True)
    yr, Sr = decay_attention_ref(q, k, v, lw, bonus=bonus, return_state=True)
    assert float(jnp.max(jnp.abs(yc - yr))) < 2e-3
    assert float(jnp.max(jnp.abs(Sc - Sr))) < 2e-3


def test_initial_state_carries():
    q, k, v, lw = _mk(1, 40, 2, 8, 8)
    # full pass == two half passes chaining the state
    y_full, S_full = chunked_decay_attention(q, k, v, lw, return_state=True)
    y1, S1 = chunked_decay_attention(
        q[:, :20], k[:, :20], v[:, :20], lw[:, :20], return_state=True
    )
    y2, S2 = chunked_decay_attention(
        q[:, 20:], k[:, 20:], v[:, 20:], lw[:, 20:],
        initial_state=S1, return_state=True,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)),
        np.asarray(y_full), atol=2e-3,
    )
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full), atol=2e-3)


def test_decode_step_matches_prefill_tail():
    """Prefill S tokens == prefill S-1 then one decode step."""
    q, k, v, lw = _mk(2, 17, 2, 8, 8)
    for bonus in [None, jnp.asarray(RNG.normal(size=(2, 8)) * 0.2, jnp.float32)]:
        y_full, S_full = chunked_decay_attention(
            q, k, v, lw, bonus=bonus, return_state=True
        )
        _, S_head = chunked_decay_attention(
            q[:, :-1], k[:, :-1], v[:, :-1], lw[:, :-1],
            bonus=bonus, return_state=True,
        )
        y1, S1 = decay_attention_step(
            q[:, -1], k[:, -1], v[:, -1], lw[:, -1], S_head, bonus=bonus
        )
        np.testing.assert_allclose(
            np.asarray(y1), np.asarray(y_full[:, -1]), atol=2e-3
        )
        np.testing.assert_allclose(np.asarray(S1), np.asarray(S_full), atol=2e-3)
