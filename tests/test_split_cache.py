"""Split (main/recent) KV cache: decode parity with teacher forcing across
families, including mid-stream flushes — the §Perf decode optimization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.transformer import LM


@pytest.mark.parametrize(
    "arch", ["stablelm_1_6b", "zamba2_7b", "seamless_m4t_medium", "qwen2_vl_72b"]
)
def test_split_cache_decode_with_flush_matches_prefill(arch):
    cfg = get_config(arch).smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(1))
    S = 11
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S)), jnp.int32)
    pos = jnp.arange(S, dtype=jnp.int32)[None]
    pos3 = jnp.broadcast_to(pos[..., None], (1, S, 3))
    use_pos = pos3 if cfg.rope == "mrope" else pos
    batch = {"tokens": toks, "positions": use_pos}
    if cfg.is_encdec:
        enc = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)) * 0.02, jnp.float32)
        batch["enc_embeds"] = enc
    full = model.prefill_logits(params, batch)

    # recent ring of 4 -> multiple amortized flushes during 11 tokens
    cache = model.init_cache(
        1, S + 4, enc_len=8 if cfg.is_encdec else 0, recent_size=4
    )
    if cfg.is_encdec:
        ek = model._run_encoder(params, batch["enc_embeds"])
        ck, cv = [], []
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["decoder"])
            kk, vv = model._encoder_kv(lp["xattn"], ek)
            ck.append(kk)
            cv.append(vv)
        cache["layers"]["cross"] = (jnp.stack(ck), jnp.stack(cv))
    logits = None
    n_flushes = 0
    for t in range(S):
        db = {"tokens": toks[:, t : t + 1], "positions": use_pos[:, t : t + 1]}
        logits, cache = model.decode_step(params, db, cache)
        if int(cache["len_rec"]) == 4:
            cache = model.flush_cache(cache)
            n_flushes += 1
    assert n_flushes >= 2
    err = float(np.abs(np.asarray(logits) - np.asarray(full)).max())
    assert err < 5e-4, (arch, err)


def test_merge_segments_exactness():
    """Two-segment logsumexp merge == monolithic softmax attention."""
    from repro.models.attention import _attention_with_lse, merge_segments

    rng = np.random.default_rng(3)
    B, Sq, H, KV, hd, S1, S2 = 2, 3, 4, 2, 16, 7, 5
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S1 + S2, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S1 + S2, KV, hd)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(Sq)[None] + S1 + S2 - Sq, (B, Sq))

    whole, _ = _attention_with_lse(
        q, k, v, kv_len=S1 + S2, kv_offset=0, scale=hd**-0.5, q_pos=q_pos
    )
    part1 = _attention_with_lse(
        q, k[:, :S1], v[:, :S1], kv_len=S1, kv_offset=0, scale=hd**-0.5,
        q_pos=q_pos,
    )
    part2 = _attention_with_lse(
        q, k[:, S1:], v[:, S1:], kv_len=S2, kv_offset=S1, scale=hd**-0.5,
        q_pos=q_pos,
    )
    merged = merge_segments([part1, part2])
    np.testing.assert_allclose(
        np.asarray(merged), np.asarray(whole), atol=1e-5
    )


def test_empty_segment_is_inert():
    from repro.models.attention import _attention_with_lse, merge_segments

    rng = np.random.default_rng(4)
    B, Sq, H, hd, S1 = 1, 2, 2, 8, 6
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S1, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S1, H, hd)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(Sq)[None] + S1 - Sq, (B, Sq))
    full, _ = _attention_with_lse(
        q, k, v, kv_len=S1, kv_offset=0, scale=hd**-0.5, q_pos=q_pos
    )
    p1 = _attention_with_lse(
        q, k, v, kv_len=S1, kv_offset=0, scale=hd**-0.5, q_pos=q_pos
    )
    p_empty = _attention_with_lse(
        q, k, v, kv_len=0, kv_offset=S1, scale=hd**-0.5, q_pos=q_pos
    )
    merged = merge_segments([p1, p_empty])
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full), atol=1e-6)
