"""Property tests: the vectorized translation/planning fast path must be
bit-identical to the seed's scalar algorithms.

Randomized (seeded ``random.Random``, no hypothesis dependency) over:

* ``region_subarrays`` / ``region_subarray_table`` vs scalar
  ``region_subarray`` under BANK_REGION, CACHELINE_INTERLEAVED, and the
  XOR-folded variants;
* coalesced + bisected ``pa_of`` / ``contiguous_run`` / ``runs`` vs the
  seed's linear-scan semantics on randomized extent lists;
* vectorized ``plan_rows`` vs the seed's per-row scalar probe across
  allocator mixes.
"""
import random

import numpy as np
import pytest

from repro.core import pud
from repro.core.allocators import (
    Allocation,
    Extent,
    HugePageModel,
    MallocModel,
    PhysicalMemory,
    PosixMemalignModel,
)
from repro.core.dram import (
    AddressMap,
    BANK_REGION_SCHEME,
    CACHELINE_INTERLEAVED_SCHEME,
    DramGeometry,
    InterleaveScheme,
)
from repro.core.puma import PumaAllocator

SCHEMES = {
    "bank_region": BANK_REGION_SCHEME,
    "cacheline": CACHELINE_INTERLEAVED_SCHEME,
    "bank_region_xor": InterleaveScheme(
        order=BANK_REGION_SCHEME.order, xor_row_into_bank=True
    ),
    "cacheline_xor": InterleaveScheme(
        order=CACHELINE_INTERLEAVED_SCHEME.order, xor_row_into_bank=True
    ),
}

SMALL_GEO = DramGeometry(subarrays_per_bank=16)  # 128 MB


# ---------------------------------------------------------------------------
# seed-reference scalar algorithms (the pre-fast-path semantics)
# ---------------------------------------------------------------------------

def _seed_pa_of(extents, size, va_off):
    for e in extents:
        if e.va_off <= va_off < e.va_off + e.nbytes:
            return e.pa + (va_off - e.va_off)
    raise ValueError(f"offset {va_off} not mapped (size={size})")


def _seed_contiguous_run(extents, size, va_off, nbytes):
    if va_off + nbytes > extents[-1].va_off + extents[-1].nbytes:
        return None
    base = _seed_pa_of(extents, size, va_off)
    cur = va_off
    while cur < va_off + nbytes:
        for e in extents:
            if e.va_off <= cur < e.va_off + e.nbytes:
                if e.pa + (cur - e.va_off) != base + (cur - va_off):
                    return None
                cur = e.va_off + e.nbytes
                break
        else:
            return None
    return base


def _random_extents(rnd: random.Random, total_pa: int):
    """A randomized extent list: contiguous VA cover, random PA placement
    with occasional deliberately PA-adjacent neighbours (coalesce bait)."""
    n = rnd.randrange(1, 20)
    sizes = [rnd.choice([64, 256, 1024, 4096, 8192]) for _ in range(n)]
    extents, va = [], 0
    for s in sizes:
        if extents and rnd.random() < 0.4:
            prev = extents[-1]
            pa = prev.pa + prev.nbytes  # physically adjacent: must coalesce
        else:
            pa = rnd.randrange(0, (total_pa - s) // 64) * 64
        extents.append(Extent(va, pa, s))
        va += s
    order = list(range(n))
    rnd.shuffle(order)  # constructor must sort by va_off
    return [extents[i] for i in order], va


@pytest.mark.parametrize("scheme_name", sorted(SCHEMES))
def test_region_subarrays_matches_scalar(scheme_name):
    amap = AddressMap(SMALL_GEO, SCHEMES[scheme_name])
    rb = amap.region_bytes
    rng = np.random.default_rng(42)
    pas = rng.integers(0, amap.total_bytes // rb, 4096, dtype=np.int64) * rb
    batch = amap.region_subarrays(pas)
    scalar = np.array([amap.region_subarray(int(p)) for p in pas])
    np.testing.assert_array_equal(batch, scalar)
    # memoized table agrees too, and is cached
    table = amap.region_subarray_table()
    np.testing.assert_array_equal(table[pas // rb], scalar)
    assert amap.region_subarray_table() is table


@pytest.mark.parametrize("scheme_name", sorted(SCHEMES))
def test_regions_in_range_matches_scalar(scheme_name):
    amap = AddressMap(SMALL_GEO, SCHEMES[scheme_name])
    rb = amap.region_bytes
    rnd = random.Random(7)
    for _ in range(50):
        pa = rnd.randrange(0, amap.total_bytes // 2)
        nbytes = rnd.randrange(0, 64 * rb)
        got = amap.regions_in_range(pa, nbytes)
        first = -(-pa // rb)
        last = (pa + nbytes) // rb
        want = [(r * rb, amap.region_subarray(r * rb)) for r in range(first, last)]
        assert got == want


def test_extent_normalization_coalesces_and_sorts():
    rnd = random.Random(0)
    for _ in range(100):
        extents, size = _random_extents(rnd, SMALL_GEO.total_bytes)
        a = Allocation(0x1000, size, list(extents), "test")
        # sorted, non-overlapping, same VA cover
        offs = [e.va_off for e in a.extents]
        assert offs == sorted(offs)
        assert sum(e.nbytes for e in a.extents) == size
        # maximality: no two neighbours are both VA- and PA-adjacent
        for e1, e2 in zip(a.extents, a.extents[1:]):
            assert not (
                e1.va_off + e1.nbytes == e2.va_off
                and e1.pa + e1.nbytes == e2.pa
            )


def test_pa_of_and_contiguous_run_match_seed_semantics():
    rnd = random.Random(1)
    for _ in range(60):
        extents, size = _random_extents(rnd, SMALL_GEO.total_bytes)
        seed_exts = sorted(extents, key=lambda e: e.va_off)
        a = Allocation(0x1000, size, list(extents), "test")
        for _ in range(40):
            off = rnd.randrange(0, size)
            assert a.pa_of(off) == _seed_pa_of(seed_exts, size, off)
            n = rnd.randrange(1, size - off + 1)
            assert a.contiguous_run(off, n) == _seed_contiguous_run(
                seed_exts, size, off, n
            )
        with pytest.raises(ValueError):
            a.pa_of(size + sum(e.nbytes for e in seed_exts))
        with pytest.raises(ValueError):
            a.pa_of(-1)


def test_runs_cover_range_and_are_maximal():
    rnd = random.Random(2)
    for _ in range(60):
        extents, size = _random_extents(rnd, SMALL_GEO.total_bytes)
        a = Allocation(0x1000, size, list(extents), "test")
        off = rnd.randrange(0, size)
        n = rnd.randrange(1, size - off + 1)
        runs = list(a.runs(off, n))
        assert sum(r[1] for r in runs) == n
        # every byte agrees with pa_of; runs never merge across a PA break
        cur = off
        for pa, ln in runs:
            assert a.pa_of(cur) == pa
            assert a.pa_of(cur + ln - 1) == pa + ln - 1
            cur += ln
        for (pa1, n1), (pa2, _) in zip(runs, runs[1:]):
            assert pa1 + n1 != pa2  # else it was not maximal


@pytest.mark.parametrize("scheme_name", ["bank_region", "cacheline"])
def test_plan_rows_matches_scalar_probe(scheme_name):
    amap = AddressMap(SMALL_GEO, SCHEMES[scheme_name])
    mem = PhysicalMemory(amap, seed=5, n_huge_pages=24, occupancy=0.2)
    region = amap.region_bytes
    puma = PumaAllocator(mem)
    puma.pim_preallocate(8)
    allocators = {
        "malloc": MallocModel(mem),
        "memalign": PosixMemalignModel(mem),
        "huge": HugePageModel(mem),
        "huge_heap": HugePageModel(mem, "heap"),
    }
    rnd = random.Random(9)
    for op, n_ops in [("zero", 1), ("copy", 2), ("and", 3)]:
        for kind, al in allocators.items():
            size = rnd.randrange(1, 6 * region)
            operands = [al.alloc(size) for _ in range(n_ops)]
            plan = pud.plan_rows(op, operands, amap)
            # scalar probe row by row (the seed algorithm)
            n_full, tail = divmod(size, region)
            n_rows = n_full + (1 if tail else 0)
            assert plan.n_rows == n_rows
            for r in range(n_rows):
                sas = [
                    pud._row_subarray(a, r, region, amap) for a in operands
                ]
                want = sas[0] is not None and all(s == sas[0] for s in sas)
                assert plan.in_pud[r] == want, (op, kind, r)
        # PUMA aligned operands plan fully in-PUD
        size = rnd.randrange(1, 4 * region)
        operands = [puma.pim_alloc(size)]
        while len(operands) < n_ops:
            operands.append(puma.pim_alloc_align(size, operands[0]))
        plan = pud.plan_rows(op, operands, amap)
        assert plan.in_pud == [True] * plan.n_rows
        for a in operands:
            puma.pim_free(a)


def test_row_subarray_table_cached_per_amap():
    amap1 = AddressMap(SMALL_GEO, BANK_REGION_SCHEME)
    amap2 = AddressMap(SMALL_GEO, CACHELINE_INTERLEAVED_SCHEME)
    mem = PhysicalMemory(amap1, seed=0, n_huge_pages=16)
    a = MallocModel(mem).alloc(64 * 1024)
    t1 = pud.row_subarray_table(a, amap1)
    assert pud.row_subarray_table(a, amap1) is t1  # memoized
    t2 = pud.row_subarray_table(a, amap2)          # second map: own entry
    assert pud.row_subarray_table(a, amap2) is t2
    assert pud.row_subarray_table(a, amap1) is t1


def test_ordered_array_total_free_running_count():
    amap = AddressMap(SMALL_GEO, CACHELINE_INTERLEAVED_SCHEME)
    mem = PhysicalMemory(amap, seed=0, n_huge_pages=32)
    puma = PumaAllocator(mem)
    n = puma.pim_preallocate(4)
    assert puma.free_regions() == n
    assert n == sum(puma.free_counts().values())
    a = puma.pim_alloc(5 * amap.region_bytes)
    assert puma.free_regions() == n - 5
    assert puma.free_regions() == sum(puma.free_counts().values())
    puma.pim_free(a)
    assert puma.free_regions() == n
    assert puma.free_regions() == sum(puma.free_counts().values())


# ---------------------------------------------------------------------------
# Channel view: batch (channel, rank, bank, subarray) decode and the
# channel-striping allocators, pinned to scalar decode / channels=1 behavior.
# ---------------------------------------------------------------------------

MULTI_GEO = DramGeometry(channels=8, subarrays_per_bank=2)  # 128 MB, 8 ch


@pytest.mark.parametrize("scheme_name", sorted(SCHEMES))
def test_region_coords_matches_scalar_decode(scheme_name):
    amap = AddressMap(MULTI_GEO, SCHEMES[scheme_name])
    rng = np.random.default_rng(11)
    pas = rng.integers(0, amap.total_bytes, 4096, dtype=np.int64)
    pas -= pas % amap.region_bytes
    chan, rank, bank, sa = amap.region_coords(pas)
    for i in rng.choice(len(pas), 200, replace=False):
        c = amap.decode(int(pas[i]))
        assert (chan[i], rank[i], bank[i], sa[i]) == (
            c.channel, c.rank, c.bank, c.subarray
        ), (scheme_name, hex(int(pas[i])))


@pytest.mark.parametrize("scheme_name", sorted(SCHEMES))
def test_channel_of_subarray_matches_decode(scheme_name):
    """gsa % channels is the decoded channel — the no-re-decode shortcut the
    controllers and the striping allocators rely on."""
    amap = AddressMap(MULTI_GEO, SCHEMES[scheme_name])
    rng = np.random.default_rng(12)
    pas = rng.integers(0, amap.total_bytes, 2048, dtype=np.int64)
    pas -= pas % amap.region_bytes
    gsa = amap.region_subarrays(pas)
    chan, _, _, _ = amap.region_coords(pas)
    np.testing.assert_array_equal(amap.channel_of_subarray(gsa), chan)
    # scalar form agrees too
    assert amap.channel_of_subarray(int(gsa[0])) == int(chan[0])


def test_region_channels_matches_region_coords():
    amap = AddressMap(MULTI_GEO, BANK_REGION_SCHEME)
    rng = np.random.default_rng(13)
    pas = rng.integers(0, amap.total_bytes, 2048, dtype=np.int64)
    pas -= pas % amap.region_bytes
    chan, _, _, _ = amap.region_coords(pas)
    np.testing.assert_array_equal(amap.region_channels(pas), chan)


def test_cacheline_region_channels_all_zero():
    """Region bases zero the channel bits under cacheline interleaving: a
    region is a cross-channel stripe, so the partition is one queue."""
    amap = AddressMap(MULTI_GEO, CACHELINE_INTERLEAVED_SCHEME)
    rb = amap.region_bytes
    pas = np.arange(amap.total_bytes // rb, dtype=np.int64) * rb
    assert (amap.region_channels(pas) == 0).all()
    assert (amap.channel_of_subarray(amap.region_subarrays(pas)) == 0).all()


@pytest.mark.parametrize("scheme_name", ["bank_region", "cacheline"])
def test_striping_at_channels1_identical_to_unstriped(scheme_name):
    """stripe_channels=True at channels=1 is bit-for-bit the plain
    allocator: same extents, same order, same free-region accounting."""
    amap = AddressMap(
        DramGeometry(channels=1, subarrays_per_bank=16),
        SCHEMES[scheme_name],
    )
    rnd = random.Random(21)
    sizes = [rnd.randrange(1, 4 * amap.region_bytes) for _ in range(12)]

    def run(stripe):
        mem = PhysicalMemory(amap, seed=8, n_huge_pages=24, occupancy=0.2)
        al = PumaAllocator(mem, stripe_channels=stripe)
        al.pim_preallocate(8)
        out = []
        allocs = []
        for i, s in enumerate(sizes):
            a = al.pim_alloc(s)
            allocs.append(a)
            out.append([(e.va_off, e.pa, e.nbytes) for e in a.extents])
            if i % 3 == 2:
                al.pim_free(allocs.pop(rnd.randrange(len(allocs))))
        out.append(al.free_regions())
        return out

    rnd_state = rnd.getstate()
    plain = run(False)
    rnd.setstate(rnd_state)
    striped = run(True)
    assert plain == striped


def test_striped_alloc_spreads_channels():
    amap = AddressMap(MULTI_GEO, BANK_REGION_SCHEME)
    mem = PhysicalMemory(amap, seed=9, n_huge_pages=32, huge_scatter=1.0)
    al = PumaAllocator(mem, stripe_channels=True)
    al.pim_preallocate(32)
    a = al.pim_alloc(16 * amap.region_bytes)
    pas = np.array([e.pa for e in a.extents], dtype=np.int64)
    used = set(amap.region_channels(pas).tolist())
    assert len(used) >= 4   # regions landed on many channels, not one
    rep = al.channel_report()
    assert rep["channels"] == 8
    assert rep["used_balance"] > 0.4
