"""The paper's §1 observations + allocator model invariants."""
import numpy as np
import pytest

from repro.core.allocators import (
    HUGE_PAGE,
    HugePageModel,
    MallocModel,
    PhysicalMemory,
    PosixMemalignModel,
)
from repro.core.dram import AddressMap
from repro.core.puma import PumaAllocator
from repro.core import pud

AMAP = AddressMap()
SIZES_BITS = [2_000, 32_000, 512_000, 6_000_000]


def _fraction(mk_alloc, size, op="and", nops=3, reps=10):
    fr = []
    for rep in range(reps):
        mem = PhysicalMemory(AMAP, seed=rep)
        al = mk_alloc(mem)
        ops = [al.alloc(size) for _ in range(nops)]
        fr.append(pud.plan_rows(op, ops, AMAP).pud_fraction)
    return float(np.mean(fr))


@pytest.mark.parametrize("bits", SIZES_BITS)
def test_malloc_zero_percent(bits):
    """Paper obs (i): malloc -> 0% PUD-executable at every size."""
    assert _fraction(lambda m: MallocModel(m), bits // 8) == 0.0


@pytest.mark.parametrize("bits", SIZES_BITS)
def test_posix_memalign_zero_percent(bits):
    """Paper obs (i): posix_memalign -> 0% (virtually aligned only)."""
    assert _fraction(lambda m: PosixMemalignModel(m), bits // 8) == 0.0


def test_hugepage_partial():
    """Paper obs (ii): huge pages cap out well below 100% ("up to 60%")."""
    for bits in [32_000, 512_000, 6_000_000]:
        f = _fraction(lambda m: HugePageModel(m, "mmap"), bits // 8)
        assert 0.0 < f <= 0.75, (bits, f)


def test_puma_full():
    """PUMA: ~100% at every size (pim_alloc + pim_alloc_align)."""
    for bits in SIZES_BITS:
        size = max(1, bits // 8)
        mem = PhysicalMemory(AMAP, seed=0)
        pa = PumaAllocator(mem)
        pa.pim_preallocate(64)
        A = pa.pim_alloc(size)
        B = pa.pim_alloc_align(size, A)
        C = pa.pim_alloc_align(size, A)
        plan = pud.plan_rows("and", [A, B, C], AMAP)
        assert plan.pud_fraction == 1.0, (bits, plan.pud_fraction)


def test_allocations_dont_overlap_physically():
    mem = PhysicalMemory(AMAP, seed=3)
    allocs = []
    for mk in (MallocModel(mem), PosixMemalignModel(mem), HugePageModel(mem)):
        allocs.extend(mk.alloc(50_000) for _ in range(4))
    pa = PumaAllocator(mem)
    pa.pim_preallocate(16)
    allocs.extend(pa.pim_alloc(50_000) for _ in range(4))
    claimed = set()
    for a in allocs:
        for e in a.extents:
            rng = (e.pa, e.pa + e.nbytes)
            for lo, hi in claimed:
                assert rng[1] <= lo or rng[0] >= hi, "physical overlap"
            claimed.add(rng)


def test_hugepage_heap_small_sizes_fail_row_alignment():
    f = _fraction(lambda m: HugePageModel(m, "heap"), 250)
    assert f == 0.0
