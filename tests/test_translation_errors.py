"""Edge cases of the VA->PA translation error paths (ISSUE 7 satellite):
unmapped offsets, zero-size allocations, and out-of-range probes raise the
typed :class:`TranslationError` (a ``ValueError``, so legacy pins hold)."""
import numpy as np
import pytest

from repro.core.allocators import (
    HugePageModel,
    MallocModel,
    PhysicalMemory,
)
from repro.core.dram import AddressMap
from repro.core.puma import PumaAllocator
from repro.robustness import TranslationError

AMAP = AddressMap()
REGION = AMAP.region_bytes


def puma(n_huge=4):
    mem = PhysicalMemory(AMAP, n_huge_pages=16)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(n_huge)
    return pa


@pytest.fixture(params=["puma", "malloc", "huge"])
def alloc(request):
    if request.param == "puma":
        return puma().pim_alloc(3 * REGION + 100)
    mem = PhysicalMemory(AMAP, n_huge_pages=16)
    al = MallocModel(mem) if request.param == "malloc" else HugePageModel(mem)
    return al.alloc(3 * REGION + 100)


def test_pa_of_out_of_range_raises_typed(alloc):
    padded = sum(e.nbytes for e in alloc.extents)
    for off in (-1, padded, padded + REGION, 2**40):
        with pytest.raises(TranslationError) as ei:
            alloc.pa_of(off)
        assert isinstance(ei.value, ValueError)       # legacy pin holds
        assert ei.value.ctx["va_off"] == off
        assert ei.value.ctx["size"] == alloc.size


def test_pa_of_boundaries_are_exact(alloc):
    padded = sum(e.nbytes for e in alloc.extents)
    assert alloc.pa_of(0) == alloc.extents[0].pa
    last = alloc.extents[-1]
    assert alloc.pa_of(padded - 1) == last.pa + last.nbytes - 1
    with pytest.raises(TranslationError):
        alloc.pa_of(padded)


def test_contiguous_run_unmapped_start_raises(alloc):
    padded = sum(e.nbytes for e in alloc.extents)
    for off in (-1, padded, padded + 5):
        with pytest.raises(TranslationError):
            alloc.contiguous_run(off, 1)


def test_contiguous_run_end_overflow_returns_none(alloc):
    # mapped start, end past the mapping: not a contiguous run, not an error
    padded = sum(e.nbytes for e in alloc.extents)
    assert alloc.contiguous_run(padded - 1, 2) is None
    assert alloc.contiguous_run(0, padded + 1) is None


def test_runs_raises_on_unmapped_span(alloc):
    padded = sum(e.nbytes for e in alloc.extents)
    with pytest.raises(TranslationError):
        list(alloc.runs(padded - 10, 20))
    # full-span walk covers every byte exactly once
    total = sum(n for _, n in alloc.runs(0, padded))
    assert total == padded


def test_zero_size_allocation_translates_nowhere():
    pa = puma()
    total = pa.free_regions()
    a = pa.pim_alloc(0)
    assert a is not None and a.size == 0 and a.extents == []
    assert pa.free_regions() == total          # consumed no regions
    for off in (0, 1, -1):
        with pytest.raises(TranslationError):
            a.pa_of(off)
        with pytest.raises(TranslationError):
            a.contiguous_run(off, 1)
    assert list(a.runs(0, 0)) == []            # empty walk is legal
    pa.pim_free(a)                             # and it is recyclable
    assert pa.free_regions() == total


def test_translation_error_is_catchable_as_value_error(alloc):
    try:
        alloc.pa_of(-5)
    except ValueError as e:                    # pre-taxonomy call sites
        assert isinstance(e, TranslationError)
    else:
        pytest.fail("expected a ValueError")
