"""PUD executability planning + functional execution vs numpy oracle."""
import numpy as np
import pytest

from repro.core.allocators import HugePageModel, MallocModel, PhysicalMemory
from repro.core.dram import AddressMap, DramGeometry
from repro.core.puma import PumaAllocator
from repro.core import pud

# Full-size map for the planning/speedup tests.
AMAP = AddressMap()
# Small 128 MB geometry so the functional tests can hold real phys memory.
SMALL = AddressMap(DramGeometry(subarrays_per_bank=16))


def _write(phys, alloc, data):
    for e in alloc.extents:
        n = min(e.nbytes, alloc.size - e.va_off)
        if n > 0:
            phys[e.pa : e.pa + n] = data[e.va_off : e.va_off + n]


def _read(phys, alloc):
    out = np.zeros(alloc.size, np.uint8)
    for e in alloc.extents:
        n = min(e.nbytes, alloc.size - e.va_off)
        if n > 0:
            out[e.va_off : e.va_off + n] = phys[e.pa : e.pa + n]
    return out


@pytest.mark.parametrize("op", ["zero", "copy", "and", "or", "not"])
@pytest.mark.parametrize("alloc_kind", ["malloc", "huge", "puma"])
def test_execute_matches_numpy(op, alloc_kind):
    size = 3 * SMALL.region_bytes + 123
    mem = PhysicalMemory(SMALL, seed=1, n_huge_pages=16, occupancy=0.1)
    n_ops = pud.N_OPERANDS[op]
    if alloc_kind == "malloc":
        al = MallocModel(mem)
        operands = [al.alloc(size) for _ in range(n_ops)]
    elif alloc_kind == "huge":
        al = HugePageModel(mem)
        operands = [al.alloc(size) for _ in range(n_ops)]
    else:
        al = PumaAllocator(mem)
        al.pim_preallocate(8)
        operands = [al.pim_alloc(size)]
        while len(operands) < n_ops:
            operands.append(al.pim_alloc_align(size, operands[0]))

    phys = np.random.default_rng(0).integers(
        0, 256, SMALL.total_bytes, dtype=np.uint8
    )
    srcs = [
        np.random.default_rng(i + 1).integers(0, 256, size, dtype=np.uint8)
        for i in range(n_ops)
    ]
    for a, data in zip(operands, srcs):
        _write(phys, a, data)

    plan = pud.execute_op(op, operands, phys, SMALL)
    got = _read(phys, operands[-1])

    if op == "zero":
        want = np.zeros(size, np.uint8)
    elif op == "copy":
        want = srcs[0]
    elif op == "not":
        want = ~srcs[0]
    elif op == "and":
        want = srcs[0] & srcs[1]
    else:
        want = srcs[0] | srcs[1]
    np.testing.assert_array_equal(got, want)
    if alloc_kind == "puma":
        assert plan.pud_fraction == 1.0


def test_speedup_grows_with_size():
    model = pud.PudCostModel()
    speedups = []
    for bits in [32_000, 512_000, 6_000_000]:
        size = bits // 8
        mem = PhysicalMemory(AMAP, seed=0)
        pa = PumaAllocator(mem)
        pa.pim_preallocate(64)
        A = pa.pim_alloc(size)
        B = pa.pim_alloc_align(size, A)
        C = pa.pim_alloc_align(size, A)
        r = pud.simulate_op("and", [A, B, C], AMAP, model)
        mem2 = PhysicalMemory(AMAP, seed=0)
        mal = MallocModel(mem2)
        rm = pud.simulate_op("and", [mal.alloc(size) for _ in range(3)], AMAP, model)
        speedups.append(rm.t_ns / r.t_ns)
    assert speedups == sorted(speedups), speedups
    assert speedups[-1] > 3.0


def test_adaptive_never_slower_than_cpu():
    model = pud.PudCostModel()
    mem = PhysicalMemory(AMAP, seed=0)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(4)
    A = pa.pim_alloc(100)
    B = pa.pim_alloc_align(100, A)
    C = pa.pim_alloc_align(100, A)
    r = pud.simulate_op("and", [A, B, C], AMAP, model, adaptive=True)
    assert r.t_ns <= r.t_cpu_ns


def test_plan_partial_row_padding_rules():
    """PUMA owns padded regions -> partial tail row still runs in PUD;
    heap-packed hugepage allocations do not own the tail -> CPU."""
    mem = PhysicalMemory(SMALL, seed=0, n_huge_pages=16)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(4)
    size = SMALL.region_bytes // 2
    A = pa.pim_alloc(size)
    plan = pud.plan_rows("zero", [A], SMALL)
    assert plan.n_rows == 1 and plan.in_pud == [True] and plan.tail_bytes == 0

    heap = HugePageModel(mem, "heap")
    B = heap.alloc(size)
    plan = pud.plan_rows("zero", [B], SMALL)
    assert plan.tail_bytes == size and plan.in_pud == [False]
