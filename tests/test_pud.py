"""PUD executability planning + functional execution vs numpy oracle."""
import numpy as np
import pytest

from repro.core.allocators import HugePageModel, MallocModel, PhysicalMemory
from repro.core.dram import AddressMap, DramGeometry
from repro.core.puma import PumaAllocator
from repro.core import pud

# Full-size map for the planning/speedup tests.
AMAP = AddressMap()
# Small 128 MB geometry so the functional tests can hold real phys memory.
SMALL = AddressMap(DramGeometry(subarrays_per_bank=16))


def _write(phys, alloc, data):
    for e in alloc.extents:
        n = min(e.nbytes, alloc.size - e.va_off)
        if n > 0:
            phys[e.pa : e.pa + n] = data[e.va_off : e.va_off + n]


def _read(phys, alloc):
    out = np.zeros(alloc.size, np.uint8)
    for e in alloc.extents:
        n = min(e.nbytes, alloc.size - e.va_off)
        if n > 0:
            out[e.va_off : e.va_off + n] = phys[e.pa : e.pa + n]
    return out


@pytest.mark.parametrize("op", ["zero", "copy", "and", "or", "not"])
@pytest.mark.parametrize("alloc_kind", ["malloc", "huge", "puma"])
def test_execute_matches_numpy(op, alloc_kind):
    size = 3 * SMALL.region_bytes + 123
    mem = PhysicalMemory(SMALL, seed=1, n_huge_pages=16, occupancy=0.1)
    n_ops = pud.N_OPERANDS[op]
    if alloc_kind == "malloc":
        al = MallocModel(mem)
        operands = [al.alloc(size) for _ in range(n_ops)]
    elif alloc_kind == "huge":
        al = HugePageModel(mem)
        operands = [al.alloc(size) for _ in range(n_ops)]
    else:
        al = PumaAllocator(mem)
        al.pim_preallocate(8)
        operands = [al.pim_alloc(size)]
        while len(operands) < n_ops:
            operands.append(al.pim_alloc_align(size, operands[0]))

    phys = np.random.default_rng(0).integers(
        0, 256, SMALL.total_bytes, dtype=np.uint8
    )
    srcs = [
        np.random.default_rng(i + 1).integers(0, 256, size, dtype=np.uint8)
        for i in range(n_ops)
    ]
    for a, data in zip(operands, srcs):
        _write(phys, a, data)

    plan = pud.execute_op(op, operands, phys, SMALL)
    got = _read(phys, operands[-1])

    if op == "zero":
        want = np.zeros(size, np.uint8)
    elif op == "copy":
        want = srcs[0]
    elif op == "not":
        want = ~srcs[0]
    elif op == "and":
        want = srcs[0] & srcs[1]
    else:
        want = srcs[0] | srcs[1]
    np.testing.assert_array_equal(got, want)
    if alloc_kind == "puma":
        assert plan.pud_fraction == 1.0


def test_speedup_grows_with_size():
    model = pud.PudCostModel()
    speedups = []
    for bits in [32_000, 512_000, 6_000_000]:
        size = bits // 8
        mem = PhysicalMemory(AMAP, seed=0)
        pa = PumaAllocator(mem)
        pa.pim_preallocate(64)
        A = pa.pim_alloc(size)
        B = pa.pim_alloc_align(size, A)
        C = pa.pim_alloc_align(size, A)
        r = pud.simulate_op("and", [A, B, C], AMAP, model)
        mem2 = PhysicalMemory(AMAP, seed=0)
        mal = MallocModel(mem2)
        rm = pud.simulate_op("and", [mal.alloc(size) for _ in range(3)], AMAP, model)
        speedups.append(rm.t_ns / r.t_ns)
    assert speedups == sorted(speedups), speedups
    assert speedups[-1] > 3.0


def test_adaptive_never_slower_than_cpu():
    model = pud.PudCostModel()
    mem = PhysicalMemory(AMAP, seed=0)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(4)
    A = pa.pim_alloc(100)
    B = pa.pim_alloc_align(100, A)
    C = pa.pim_alloc_align(100, A)
    r = pud.simulate_op("and", [A, B, C], AMAP, model, adaptive=True)
    assert r.t_ns <= r.t_cpu_ns


def test_plan_partial_row_padding_rules():
    """PUMA owns padded regions -> partial tail row still runs in PUD;
    heap-packed hugepage allocations do not own the tail -> CPU."""
    mem = PhysicalMemory(SMALL, seed=0, n_huge_pages=16)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(4)
    size = SMALL.region_bytes // 2
    A = pa.pim_alloc(size)
    plan = pud.plan_rows("zero", [A], SMALL)
    assert plan.n_rows == 1 and plan.in_pud == [True] and plan.tail_bytes == 0

    heap = HugePageModel(mem, "heap")
    B = heap.alloc(size)
    plan = pud.plan_rows("zero", [B], SMALL)
    assert plan.tail_bytes == size and plan.in_pud == [False]


# ---------------------------------------------------------------------------
# Channel-parallel model: channels=1 must reproduce the single-channel seed
# semantics bit for bit, and multi-channel execution stays functionally exact.
# ---------------------------------------------------------------------------

from repro.core.controller import ControllerConfig, DramController
from repro.core.dram import BANK_REGION_SCHEME, CACHELINE_INTERLEAVED_SCHEME

_SCHEMES_1CH = {
    "bank_region": BANK_REGION_SCHEME,
    "cacheline": CACHELINE_INTERLEAVED_SCHEME,
}


def _seed_serial_t_ns(op, operands, amap, model):
    """The pre-channel-model pricing: PUD rows as one serial burst."""
    plan = pud.plan_rows(op, operands, amap)
    region = amap.region_bytes
    pud_rows = sum(plan.in_pud)
    cpu_rows = plan.n_rows - pud_rows
    cpu_bytes = cpu_rows * region
    if plan.tail_bytes:
        cpu_bytes += plan.tail_bytes - region
    t = pud_rows * model.pud_row_ns(op)
    if cpu_rows:
        t += model.cpu_op_overhead_ns + model.cpu_ns(op, cpu_bytes, cpu_rows)
    elif pud_rows:
        t += model.cpu_op_overhead_ns
    return t


@pytest.mark.parametrize("scheme_name", sorted(_SCHEMES_1CH))
@pytest.mark.parametrize("alloc_kind", ["puma", "huge", "malloc"])
def test_channels1_matches_seed_serial_model(scheme_name, alloc_kind):
    """At channels=1 the channel-parallel pricing *is* the serial sum —
    exact float equality, not approx — for mixed PUD/CPU plans too."""
    amap = AddressMap(
        DramGeometry(channels=1, subarrays_per_bank=16),
        _SCHEMES_1CH[scheme_name],
    )
    model = pud.PudCostModel()
    for op in ["zero", "copy", "and"]:
        mem = PhysicalMemory(amap, seed=3, n_huge_pages=16, occupancy=0.2)
        n_ops = pud.N_OPERANDS[op]
        size = 5 * amap.region_bytes + 321
        if alloc_kind == "puma":
            al = PumaAllocator(mem)
            al.pim_preallocate(8)
            operands = [al.pim_alloc(size)]
            while len(operands) < n_ops:
                operands.append(al.pim_alloc_align(size, operands[0]))
        elif alloc_kind == "huge":
            operands = [HugePageModel(mem).alloc(size) for _ in range(n_ops)]
        else:
            operands = [MallocModel(mem).alloc(size) for _ in range(n_ops)]
        r = pud.simulate_op(op, operands, amap, model, adaptive=False)
        assert r.t_ns == _seed_serial_t_ns(op, operands, amap, model), op
        if r.rows_per_channel is not None:
            assert len(r.rows_per_channel) == 1
            plan = pud.plan_rows(op, operands, amap)
            assert r.rows_per_channel[0] == sum(plan.in_pud)


def test_channels1_adaptive_identical_to_seed():
    """The adaptive decision point is unchanged at channels=1: simulate_op
    picks PUD iff the serial-seed pricing would."""
    amap = AddressMap(
        DramGeometry(channels=1, subarrays_per_bank=16), BANK_REGION_SCHEME
    )
    mem = PhysicalMemory(amap, seed=4, n_huge_pages=16)
    model = pud.PudCostModel()
    al = PumaAllocator(mem)
    al.pim_preallocate(8)
    for size in [64, 4096, amap.region_bytes, 4 * amap.region_bytes]:
        a = al.pim_alloc(size)
        r = pud.simulate_op("zero", [a], amap, model, adaptive=True)
        t_seed = _seed_serial_t_ns("zero", [a], amap, model)
        t_cpu = model.cpu_op_overhead_ns + model.cpu_ns(
            "zero", size, max(pud.plan_rows("zero", [a], amap).n_rows, 1)
        )
        assert r.t_ns == min(t_seed, t_cpu)
        al.pim_free(a)


@pytest.mark.parametrize("op", ["zero", "copy", "and", "or", "not"])
def test_execute_matches_numpy_multichannel(op):
    """Channel-partitioned dispatch order writes the same bytes as the
    whole-buffer numpy op (channels=4, striped PUMA placement)."""
    amap = AddressMap(
        DramGeometry(channels=4, subarrays_per_bank=4), BANK_REGION_SCHEME
    )
    size = 3 * amap.region_bytes + 123
    mem = PhysicalMemory(amap, seed=1, n_huge_pages=16, huge_scatter=1.0)
    al = PumaAllocator(mem, stripe_channels=True)
    al.pim_preallocate(16)
    n_ops = pud.N_OPERANDS[op]
    operands = [al.pim_alloc(size)]
    while len(operands) < n_ops:
        operands.append(al.pim_alloc_align(size, operands[0]))

    phys = np.random.default_rng(0).integers(
        0, 256, amap.total_bytes, dtype=np.uint8
    )
    srcs = [
        np.random.default_rng(i + 1).integers(0, 256, size, dtype=np.uint8)
        for i in range(n_ops)
    ]
    for a, data in zip(operands, srcs):
        _write(phys, a, data)

    ctrl = DramController(amap, ControllerConfig())
    plan = pud.execute_op(op, operands, phys, amap, controller=ctrl)
    got = _read(phys, operands[-1])

    if op == "zero":
        want = np.zeros(size, np.uint8)
    elif op == "copy":
        want = srcs[0]
    elif op == "not":
        want = ~srcs[0]
    elif op == "and":
        want = srcs[0] & srcs[1]
    else:
        want = srcs[0] | srcs[1]
    np.testing.assert_array_equal(got, want)
    assert plan.pud_fraction == 1.0
    # the execution traffic landed on the controllers, striped
    rep = ctrl.occupancy_report()
    assert sum(rep["pud_rows"]) == sum(plan.in_pud)
    assert rep["pud_row_balance"] >= 0.5   # 4 rows over 4 channels, >=2 active


def test_multichannel_striped_faster_than_stacked():
    """The tentpole effect: striped placement divides the in-DRAM burst
    time by ~the channel count versus single-channel placement."""
    amap = AddressMap(
        DramGeometry(channels=8, subarrays_per_bank=16), BANK_REGION_SCHEME
    )
    size = 128 * 1024
    mem = PhysicalMemory(amap, seed=0, n_huge_pages=64, huge_scatter=1.0)
    striped_al = PumaAllocator(mem, stripe_channels=True)
    striped_al.pim_preallocate(32)
    stacked_al = PumaAllocator(mem, stripe_channels=False)
    stacked_al.pim_preallocate(32)
    a = striped_al.pim_alloc(size)
    b = stacked_al.pim_alloc(size)
    rs = pud.simulate_op("zero", [a], amap, adaptive=False)
    rk = pud.simulate_op("zero", [b], amap, adaptive=False)
    assert rs.pud_fraction == rk.pud_fraction == 1.0
    # free regions need not exist in every channel; striping still spreads
    # the rows near-evenly over the channels that do have space
    assert rs.channel_balance > 0.8
    assert rk.channel_balance == pytest.approx(1 / 8)
    assert rk.t_ns / rs.t_ns > 4.0
