"""Data pipeline: determinism, packing masks, prefetch iterator."""
import numpy as np

from repro.data.pipeline import DataConfig, DataIterator, synth_batch


CFG = DataConfig(vocab_size=1000, seq_len=128, batch_per_shard=4)


def test_deterministic_addressing():
    a = synth_batch(CFG, step=7, dp_rank=3)
    b = synth_batch(CFG, step=7, dp_rank=3)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_shards_differ():
    a = synth_batch(CFG, step=7, dp_rank=0)
    b = synth_batch(CFG, step=7, dp_rank=1)
    assert not np.array_equal(a["tokens"], b["tokens"])
    c = synth_batch(CFG, step=8, dp_rank=0)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_targets_shifted():
    a = synth_batch(CFG, step=0, dp_rank=0)
    # within a doc (mask==1), target == next token
    tok, tgt, mask = a["tokens"], a["targets"], a["loss_mask"]
    inside = mask[:, :-1] == 1.0
    np.testing.assert_array_equal(
        tgt[:, :-1][inside], tok[:, 1:][inside]
    )


def test_boundary_masked():
    cfg = DataConfig(vocab_size=1000, seq_len=128, batch_per_shard=4,
                     mean_doc_len=32)  # short docs: boundaries within a row
    a = synth_batch(cfg, step=3, dp_rank=0)
    assert (a["loss_mask"] == 0.0).sum() > 0  # some doc boundaries exist
    assert a["tokens"].min() >= 0 and a["tokens"].max() < cfg.vocab_size


def test_iterator_resumes_at_step():
    it = DataIterator(CFG, dp_rank=0, start_step=5)
    step, batch = next(it)
    it.close()
    assert step == 5
    ref = synth_batch(CFG, 5, 0)
    np.testing.assert_array_equal(batch["tokens"], ref["tokens"])
