"""Serving engine: paged decode parity with dense decode, continuous
batching under pool pressure, fork (RowClone) path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.kv_pool import KVPoolConfig
from repro.serve.engine import Request, ServeEngine
from repro.models.transformer import LM


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm_1_6b").smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    return model, params


def _pool_cfg(cfg, **kw):
    base = dict(
        num_blocks=128, block_size=8, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        n_layers=cfg.n_layers, max_seqs=8, max_blocks_per_seq=16,
        blocks_per_arena=16, policy="puma", dtype="float32",
    )
    base.update(kw)
    return KVPoolConfig(**base)


def _dense_generate(model, params, prompt, max_new):
    toks = jnp.asarray([prompt], jnp.int32)
    S = len(prompt)
    cache = model.init_cache(1, S + max_new + 1)
    batch = {"tokens": toks, "positions": jnp.arange(S, dtype=jnp.int32)[None]}
    logits, cache = model.decode_step(params, batch, cache)
    out = [int(jnp.argmax(logits[0]))]
    for t in range(max_new - 1):
        batch = {
            "tokens": jnp.asarray([[out[-1]]], jnp.int32),
            "positions": jnp.asarray([[S + t]], jnp.int32),
        }
        logits, cache = model.decode_step(params, batch, cache)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_paged_engine_matches_dense_decode(model_and_params):
    model, params = model_and_params
    cfg = model.cfg
    eng = ServeEngine(model, params, _pool_cfg(cfg), use_kernel=False)
    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 18))))
        for _ in range(4)
    ]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=6))
    done = eng.run()
    assert len(done) == 4
    for req in done:
        ref = _dense_generate(model, params, prompts[req.rid], 6)
        assert req.out == ref, (req.rid, req.out, ref)


def test_continuous_batching_under_pressure(model_and_params):
    model, params = model_and_params
    cfg = model.cfg
    # tiny pool: forces queueing + admission as slots free up
    eng = ServeEngine(
        model, params, _pool_cfg(cfg, num_blocks=32, max_seqs=2), use_kernel=False
    )
    rng = np.random.default_rng(1)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=list(rng.integers(0, 64, 6)), max_new=4))
    done = eng.run()
    assert len(done) == 5                      # everyone eventually served
    m = eng.metrics()
    assert m["tokens"] >= 5 * 3
    assert eng.pool.pool.free_tiles() == eng.pool.pool.total_tiles


def test_fork_shares_prefix(model_and_params):
    model, params = model_and_params
    cfg = model.cfg
    eng = ServeEngine(model, params, _pool_cfg(cfg), use_kernel=False)
    eng.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5, 6, 7, 8, 9], max_new=4))
    # admit + prefill via one engine step
    eng.step()
    parent_slot = next(iter(eng.live))
    forked = eng.pool.fork(parent_slot)
    assert forked is not None
    # forked sequence sees identical KV content (RowClone block copy)
    tbl = eng.pool.block_table()
    pb = tbl[parent_slot][tbl[parent_slot] >= 0]
    fb = tbl[forked][tbl[forked] >= 0]
    assert len(pb) == len(fb) and list(pb) != list(fb)
    k = np.asarray(eng.pool.k)
    v = np.asarray(eng.pool.v)
    np.testing.assert_array_equal(k[:, pb], k[:, fb])
    np.testing.assert_array_equal(v[:, pb], v[:, fb])
    # both generate the same continuation from here
    eng.live[forked] = Request(rid=1, prompt=[], max_new=4,
                               out=list(eng.live[parent_slot].out))
    done = eng.run()
    outs = {r.rid: r.out for r in done}
    assert outs[0][-3:] == outs[1][-3:]
