"""HLO collective parser: synthetic snippets + a real lowered module."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_stats

SNIPPET = """
  %ag = f32[128,256]{1,0} all-gather(f32[8,256]{1,0} %x), replica_groups={}
  %ar.1 = bf16[64]{0} all-reduce(bf16[64]{0} %y), to_apply=%add
  %tup = (f32[32]{0}, f32[16,2]{1,0}) all-reduce-start(f32[32]{0} %a, f32[16,2]{1,0} %b)
  %done = (f32[32]{0}, f32[16,2]{1,0}) all-reduce-done((f32[32]{0}, f32[16,2]{1,0}) %tup)
  %rs = f32[4]{0} reduce-scatter(f32[64]{0} %z), dimensions={0}
  %cp = u8[100]{0} collective-permute(u8[100]{0} %w), source_target_pairs={{0,1}}
"""


def test_parser_counts_and_bytes():
    st = hlo_stats.collective_stats(SNIPPET)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 128 * 256 * 4
    # -start counted once, -done skipped
    assert st["all-reduce"]["count"] == 2
    assert st["all-reduce"]["bytes"] == 64 * 2 + (32 * 4 + 16 * 2 * 4)
    assert st["reduce-scatter"]["bytes"] == 4 * 4
    assert st["collective-permute"]["bytes"] == 100


def test_parser_on_real_module():
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    x = jax.ShapeDtypeStruct((8, 8), jnp.float32,
                             sharding=NamedSharding(mesh, P()))
    hlo = jax.jit(lambda a: (a @ a).sum()).lower(x).compile().as_text()
    st = hlo_stats.collective_stats(hlo)  # single device: no collectives
    assert hlo_stats.total_collective_bytes(hlo) == sum(
        v["bytes"] for v in st.values()
    )


def test_scalar_collectives_zero_dims():
    snippet = "%r = f32[] all-reduce(f32[] %x)"
    st = hlo_stats.collective_stats(snippet)
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes"] == 4
