"""Paged KV pool: lifecycle, block tables, fork alignment, KV round-trip."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kv_pool import KVPoolConfig, PagedKVPool


def mk(policy="puma", **kw):
    cfg = KVPoolConfig(
        num_blocks=64, block_size=4, kv_heads=2, head_dim=8, n_layers=2,
        max_seqs=8, max_blocks_per_seq=16, blocks_per_arena=16,
        policy=policy, dtype="float32", **kw,
    )
    return PagedKVPool(cfg)


def test_admit_release_cycle():
    p = mk()
    slots = [p.admit(10) for _ in range(4)]
    assert all(s is not None for s in slots)
    tbl = p.block_table()
    for s in slots:
        assert (tbl[s] >= 0).sum() == 3  # ceil(10/4)
    for s in slots:
        p.release(s)
    assert p.pool.free_tiles() == p.pool.total_tiles


def test_append_token_extends_blocks():
    p = mk()
    s = p.admit(4)          # exactly one block
    assert (p.block_table()[s] >= 0).sum() == 1
    p.append_token(s)       # 5th token -> new block
    assert (p.block_table()[s] >= 0).sum() == 2
    assert p.seq_lens()[s] == 5


def test_fork_mirrors_parent_arenas():
    p = mk()
    s = p.admit(20)  # 5 blocks: parent + fork both fit one 16-block arena
    f = p.fork(s)
    tbl = p.block_table()
    arena = lambda b: b // p.cfg.blocks_per_arena
    pb = tbl[s][tbl[s] >= 0]
    fb = tbl[f][tbl[f] >= 0]
    assert len(pb) == len(fb)
    assert [arena(b) for b in pb] == [arena(b) for b in fb]


def test_kv_roundtrip():
    p = mk()
    s = p.admit(10)
    k = jnp.arange(10 * 2 * 8, dtype=jnp.float32).reshape(10, 2, 8)
    v = -k
    p.write_prompt_kv(s, 1, k, v)
    tbl = p.block_table()[s]
    blocks = tbl[tbl >= 0]
    got_k = np.asarray(p.k[1, blocks]).reshape(-1, 2, 8)[:10]
    np.testing.assert_allclose(got_k, np.asarray(k))
    # single-token write at position 10
    p.append_token(s)
    k1 = jnp.full((2, 8), 7.0)
    p.write_token_kv(s, 1, k1, -k1)
    tbl = p.block_table()[s]
    blocks = tbl[tbl >= 0]
    got = np.asarray(p.k[1, blocks]).reshape(-1, 2, 8)[10]
    np.testing.assert_allclose(got, 7.0)


def test_pool_exhaustion_rejects_admit():
    p = mk()
    got = [p.admit(64 * 4 // 2) for _ in range(3)]  # each takes half the pool
    assert got[0] is not None and got[1] is not None and got[2] is None
