"""Hypothesis property tests for the PUMA allocator invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.allocators import PhysicalMemory
from repro.core.dram import AddressMap
from repro.core.puma import PumaAllocator

AMAP = AddressMap()
REGION = AMAP.region_bytes


def fresh(n_huge=16, seed=0):
    mem = PhysicalMemory(AMAP, seed=seed, n_huge_pages=64)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(n_huge)
    return pa


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 200_000), min_size=1, max_size=12))
def test_no_region_double_allocated(sizes):
    pa = fresh()
    live = []
    for s in sizes:
        a = pa.pim_alloc(s)
        if a is None:
            break
        live.append(a)
    seen = set()
    for a in live:
        for e in a.extents:
            assert e.pa % REGION == 0
            assert e.pa not in seen
            seen.add(e.pa)


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(1, 120_000), st.booleans()),
        min_size=2, max_size=16,
    ),
    st.randoms(use_true_random=False),
)
def test_free_then_alloc_conserves_pool(ops, rnd):
    pa = fresh()
    total = pa.free_regions()
    live = []
    for size, do_free in ops:
        if do_free and live:
            pa.pim_free(live.pop(rnd.randrange(len(live))))
        else:
            a = pa.pim_alloc(size)
            if a is not None:
                live.append(a)
        used = sum(-(-a.size // REGION) for a in live)
        assert pa.free_regions() + used == total
    for a in live:
        pa.pim_free(a)
    assert pa.free_regions() == total


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 64 * REGION))
def test_alloc_align_colocates_when_space(size):
    """Paper §2: aligned allocation places region k in the same subarray as
    the hint's region k whenever that subarray has free regions."""
    pa = fresh(n_huge=16)
    A = pa.pim_alloc(size)
    B = pa.pim_alloc_align(size, A)
    assert A is not None and B is not None
    sa = lambda alloc: [AMAP.region_subarray(e.pa) for e in alloc.extents]
    sa_a, sa_b = sa(A), sa(B)
    # with a fresh pool there is always room: exact co-location
    assert sa_a == sa_b
    assert pa.stats.align_misses == 0


def test_alloc_align_requires_live_hint():
    pa = fresh()
    a = pa.pim_alloc(1000)
    pa.pim_free(a)
    assert pa.pim_alloc_align(1000, a) is None  # hashmap miss -> fail (paper)


def test_worst_fit_picks_largest_pool():
    pa = fresh(n_huge=8)
    # drain one subarray partially, worst-fit must prefer the fullest ones
    counts_before = pa.free_counts()
    a = pa.pim_alloc(REGION)
    target = AMAP.region_subarray(a.extents[0].pa)
    assert counts_before[target] == max(counts_before.values())


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_exhaustion_fails_cleanly(seed):
    pa = fresh(n_huge=1, seed=seed % 7)
    total = pa.free_regions()
    big = pa.pim_alloc((total + 1) * REGION)
    assert big is None
    assert pa.free_regions() == total  # nothing leaked
