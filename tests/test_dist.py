"""Distribution plumbing: spec filtering, logical rules, a real 8-device
SPMD train step in a subprocess, and MoE shard_map parity on a 1x1 mesh."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_filter_spec_divisibility():
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert shd.filter_spec(P("data", "model"), (32, 32), mesh) == P("data", "model")
    assert shd.filter_spec(P("data", "model"), (32, 8), mesh) == P("data", None)
    assert shd.filter_spec(P(("data", "model")), (256,), mesh) == P(("data", "model"))
    assert shd.filter_spec(P(("data", "model")), (128,), mesh) == P(None)
    # shorter spec than rank pads with None
    assert shd.filter_spec(P("data"), (16, 4), mesh) == P("data", None)


def test_logical_spec_pod_expansion():
    mesh_no_pod = _FakeMesh({"data": 2, "model": 4})
    with shd.use_mesh(mesh_no_pod):
        assert shd.logical_spec("batch") == P("data")
    mesh_pod = _FakeMesh({"pod": 2, "data": 2, "model": 4})
    with shd.use_mesh(mesh_pod):
        assert shd.logical_spec("batch") == P(("pod", "data"))


def test_constraint_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert shd.constraint(x, "batch", None) is x


def test_moe_shard_map_matches_local():
    """On a (1,1) mesh the distributed MoE must equal the local path."""
    from repro.configs.registry import get_config
    from repro.models import moe as MOE
    from repro.models.params import init_params

    cfg = get_config("granite_moe_1b_a400m").smoke()
    defs = MOE.moe_defs(cfg)
    params = init_params(jax.random.key(0), defs)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    out_local, aux_local = MOE.apply_moe(params, cfg, x)

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    with shd.use_mesh(mesh):
        out_dist, aux_dist = MOE.apply_moe(params, cfg, x)
    np.testing.assert_allclose(
        np.asarray(out_local), np.asarray(out_dist), atol=1e-5
    )
    assert abs(float(aux_local) - float(aux_dist)) < 1e-5


_SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_config
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_smoke_mesh
    from repro.launch.inputs import make_batch
    from repro.configs.base import RunShape
    from repro.models.transformer import LM
    from repro.optim import adamw as opt_mod
    from repro.train.step import build_train_step

    cfg = get_config("granite_moe_1b_a400m").smoke()
    mesh = make_smoke_mesh(2, 4)
    shd.set_mesh(mesh)
    model = LM(cfg, attn_impl="chunked", remat="full")
    params = model.init(jax.random.key(0))
    opt = opt_mod.init_opt_state(params)
    batch = make_batch(cfg, RunShape("t", 32, 4, "train"))
    step = jax.jit(build_train_step(model, opt_mod.AdamWConfig()),
                   donate_argnums=(0, 1))
    params, opt, metrics = step(params, opt, batch)
    l1 = float(metrics["loss"])

    # compare against the single-device (no-mesh) loss on the same inputs
    shd.set_mesh(None)
    model2 = LM(cfg, attn_impl="chunked", remat="full")
    params2 = model2.init(jax.random.key(0))
    l2 = float(model2.train_loss(params2, batch))
    print(json.dumps({"dist_loss": l1, "local_loss": l2}))
    """
)


def test_spmd_train_step_8_devices():
    """End-to-end: MoE model train step on a 2x4 mesh numerically matches
    the unsharded loss (run in a subprocess so the 8-device XLA_FLAGS does
    not leak into this process)."""
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # the script forces 8 *host* devices — never let jax try to
             # initialize a real accelerator plugin in the bare subprocess
             "JAX_PLATFORMS": "cpu"},
        cwd=".",
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # distributed loss == local forward loss on identical params/batch
    assert abs(rec["dist_loss"] - rec["local_loss"]) < 5e-3, rec
