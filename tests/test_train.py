"""Training loop: convergence, checkpoint/restart, failure recovery,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.models.transformer import LM
from repro.optim import adamw as opt_mod
from repro.optim import compression as comp
from repro.train.step import build_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _mk_model():
    cfg = get_config("stablelm_1_6b").smoke()
    return LM(cfg, attn_impl="naive", remat=None), cfg


def _data_cfg(cfg, seq=32, batch=4):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, batch_per_shard=batch)


def test_loss_decreases(tmp_path):
    model, cfg = _mk_model()
    tcfg = TrainerConfig(
        total_steps=40, ckpt_every=100, ckpt_dir=str(tmp_path), log_every=1000
    )
    ocfg = opt_mod.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=40)
    out = Trainer(
        model, _data_cfg(cfg, seq=64, batch=8), ocfg, tcfg, log=lambda s: None
    ).run()
    hist = [m["loss"] for _, m in out["history"]]
    assert np.mean(hist[-5:]) < np.mean(hist[:5]) - 0.5, hist


def test_checkpoint_resume_bit_exact(tmp_path):
    model, cfg = _mk_model()
    ocfg = opt_mod.AdamWConfig(warmup_steps=2, total_steps=20)

    # run 1: straight through 10 steps
    t1 = TrainerConfig(total_steps=10, ckpt_every=100, ckpt_dir=str(tmp_path / "a"),
                       log_every=1000)
    outA = Trainer(model, _data_cfg(cfg), ocfg, t1, log=lambda s: None).run()

    # run 2: 5 steps (ckpt at 5), then a fresh Trainer resumes to 10
    t2 = TrainerConfig(total_steps=5, ckpt_every=5, ckpt_dir=str(tmp_path / "b"),
                       log_every=1000)
    Trainer(model, _data_cfg(cfg), ocfg, t2, log=lambda s: None).run()
    t3 = TrainerConfig(total_steps=10, ckpt_every=100, ckpt_dir=str(tmp_path / "b"),
                       log_every=1000)
    outB = Trainer(model, _data_cfg(cfg), ocfg, t3, log=lambda s: None).run()

    for a, b in zip(jax.tree.leaves(outA["params"]), jax.tree.leaves(outB["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_recovery(tmp_path):
    model, cfg = _mk_model()
    ocfg = opt_mod.AdamWConfig(warmup_steps=2, total_steps=20)
    boom = {"armed": True}

    def failure_hook(step):
        if step == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    tcfg = TrainerConfig(total_steps=10, ckpt_every=5, ckpt_dir=str(tmp_path),
                         log_every=1000)
    out = Trainer(model, _data_cfg(cfg), ocfg, tcfg,
                  failure_hook=failure_hook, log=lambda s: None).run()
    assert out["recoveries"] == 1
    # reached the target despite the failure
    steps = [s for s, _ in out["history"]]
    assert max(steps) == 9


def test_grad_accumulation_matches_full_batch():
    model, cfg = _mk_model()
    ocfg = opt_mod.AdamWConfig(warmup_steps=0, total_steps=10)
    params = model.init(jax.random.key(0))
    opt1 = opt_mod.init_opt_state(params)
    batch = {
        k: jnp.asarray(v) for k, v in synth_batch(_data_cfg(cfg), 0, 0).items()
    }
    s1 = build_train_step(model, ocfg, accum_steps=1)
    s2 = build_train_step(model, ocfg, accum_steps=2)
    p1, _, m1 = jax.jit(s1)(params, opt1, batch)
    p2, _, m2 = jax.jit(s2)(params, opt_mod.init_opt_state(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    err = comp.init_error_state(g)
    acc = np.zeros((64, 64), np.float64)
    acc_raw = np.zeros((64, 64), np.float64)
    for step in range(50):
        gs = {"w": g["w"] * (1.0 + 0.01 * step)}
        deq, err = comp.compress_grads(gs, err)
        acc += np.asarray(deq["w"], np.float64)
        acc_raw += np.asarray(gs["w"], np.float64)
    # error feedback keeps the accumulated quantized stream close to the
    # accumulated true stream (bounded by one quantization step)
    scale = np.abs(acc_raw).max()
    assert np.abs(acc - acc_raw).max() / scale < 0.01


def test_compressed_psum_on_one_device_mesh():
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    from repro.dist.sharding import shard_map_compat as shard_map
    x = jnp.asarray(np.random.default_rng(1).normal(size=(8, 128)), jnp.float32)
    f = shard_map(
        lambda v: comp.compressed_psum(v, "data"),
        mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False,
    )
    out = f(x)
    assert float(jnp.max(jnp.abs(out - x))) < np.abs(x).max() / 100
