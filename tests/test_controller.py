"""Controller model: per-channel queues, FR-FCFS-lite pricing, PUD dispatch."""
import numpy as np
import pytest

from repro.core import pud
from repro.core.allocators import PhysicalMemory
from repro.core.controller import (
    ChannelController,
    ControllerConfig,
    DramController,
    channel_row_counts,
)
from repro.core.dram import (
    AddressMap,
    BANK_REGION_SCHEME,
    CACHELINE_INTERLEAVED_SCHEME,
    DramGeometry,
)
from repro.core.puma import PumaAllocator

CFG = ControllerConfig()
GEO8 = DramGeometry(channels=8, subarrays_per_bank=16)   # 1 GB
AMAP8 = AddressMap(GEO8, BANK_REGION_SCHEME)


def test_channel_row_counts_matches_scalar():
    rng = np.random.default_rng(0)
    gsa = rng.integers(0, GEO8.num_global_subarrays, 1000, dtype=np.int64)
    got = channel_row_counts(gsa, AMAP8)
    want = [0] * GEO8.channels
    for g in gsa.tolist():
        want[g % GEO8.channels] += 1
    assert got.tolist() == want
    assert got.sum() == len(gsa)


def test_enqueue_pud_serializes_on_one_channel():
    ch = ChannelController(0, CFG)
    t1 = ch.enqueue_pud(10, 90.0, now_ns=0.0)
    assert t1 == CFG.mode_switch_ns + 10 * 90.0   # SB -> PIM once
    t2 = ch.enqueue_pud(5, 90.0, now_ns=0.0)      # already PIM, queued behind
    assert t2 == t1 + 5 * 90.0
    assert ch.stats.mode_switches == 1
    assert ch.stats.pud_rows == 15


def test_mode_switches_charged_on_transitions():
    ch = ChannelController(0, CFG)
    t = ch.enqueue_pud(1, 90.0, now_ns=0.0)            # SB -> PIM
    t = ch.enqueue_accesses([(0, 0)], now_ns=t)        # PIM -> SB
    t = ch.enqueue_pud(1, 90.0, now_ns=t)              # SB -> PIM again
    assert ch.stats.mode_switches == 3
    assert t == 3 * CFG.mode_switch_ns + 2 * 90.0 + CFG.row_miss_ns


def test_fr_fcfs_row_hits_and_open_rows():
    ch = ChannelController(0, CFG)
    # 4 accesses to one row: 1 activation + 3 CAS
    t1 = ch.enqueue_accesses([(0, 7)] * 4)
    assert t1 == CFG.row_miss_ns + 3 * CFG.row_hit_ns
    assert (ch.stats.row_hits, ch.stats.row_misses) == (3, 1)
    # row 7 is still open in bank 0: pure hit
    t2 = ch.enqueue_accesses([(0, 7)], now_ns=t1)
    assert t2 == t1 + CFG.row_hit_ns
    # a PUD burst closes the row buffers: same access misses again
    t3 = ch.enqueue_pud(1, 90.0, now_ns=t2)
    t4 = ch.enqueue_accesses([(0, 7)], now_ns=t3)
    assert t4 == t3 + CFG.mode_switch_ns + CFG.row_miss_ns


def test_peek_pud_does_not_mutate():
    ch = ChannelController(0, CFG)
    est = ch.peek_pud(10, 90.0, now_ns=0.0)
    assert est == CFG.mode_switch_ns + 10 * 90.0
    assert ch.busy_until_ns == 0.0 and ch.mode == ch.SB
    assert ch.stats.pud_rows == 0
    assert ch.enqueue_pud(10, 90.0, now_ns=0.0) == est  # peek was exact
    # in PIM mode the peek drops the switch cost
    assert ch.peek_pud(1, 90.0, now_ns=0.0) == ch.busy_until_ns + 90.0


def test_dispatch_pud_max_over_channels():
    ctrl = DramController(AMAP8, CFG)
    # 8 rows striped over all channels vs 8 rows on channel 0
    striped = np.arange(8, dtype=np.int64)          # gsa % 8 covers 0..7
    stacked = np.zeros(8, dtype=np.int64)           # all channel 0
    d1 = ctrl.peek_pud(striped, 90.0)
    d2 = ctrl.peek_pud(stacked, 90.0)
    assert d1.latency_ns == CFG.mode_switch_ns + 1 * 90.0
    assert d2.latency_ns == CFG.mode_switch_ns + 8 * 90.0
    assert d1.balance == 1.0
    assert d2.balance == pytest.approx(1 / 8)
    got = ctrl.dispatch_pud(striped, 90.0)
    assert got.done_ns == d1.done_ns
    assert ctrl.now_ns == got.done_ns
    # a second striped op queues behind the first on every channel
    got2 = ctrl.dispatch_pud(striped, 90.0)
    assert got2.done_ns == got.done_ns + 90.0       # channels already in PIM


def test_dispatch_accesses_partitions_by_channel():
    ctrl = DramController(AMAP8, CFG)
    # one cacheline in each channel: all misses, priced in parallel
    pas = np.array(
        [c << AMAP8._shifts["channel"] for c in range(8)], dtype=np.int64
    )
    done = ctrl.dispatch_accesses(pas)
    assert done == CFG.row_miss_ns   # SB already; one activation per channel
    rep = ctrl.occupancy_report()
    assert rep["channels"] == 8
    assert all(b == CFG.row_miss_ns for b in rep["busy_ns"])


def test_occupancy_report_balance():
    ctrl = DramController(AMAP8, CFG)
    ctrl.dispatch_pud(np.arange(64, dtype=np.int64), 90.0)
    rep = ctrl.occupancy_report()
    assert rep["pud_rows"] == [8] * 8
    assert rep["pud_row_balance"] == 1.0
    assert rep["makespan_ns"] == ctrl.now_ns > 0
    assert rep["mode_switches"] == [1] * 8
    assert all(0 < f <= 1.0 for f in rep["busy_fraction"])


def test_simulate_op_with_controller_charges_contention():
    """Back-to-back ops on the same operands serialize through the queues;
    without a controller each op is priced against an idle device."""
    mem = PhysicalMemory(AMAP8, seed=0, n_huge_pages=64, huge_scatter=1.0)
    alloc = PumaAllocator(mem, stripe_channels=True)
    alloc.pim_preallocate(32)
    a = alloc.pim_alloc(256 * 1024)
    ctrl = DramController(AMAP8, CFG)
    r1 = pud.simulate_op("zero", [a], AMAP8, controller=ctrl, adaptive=False)
    span1 = ctrl.now_ns
    r2 = pud.simulate_op("zero", [a], AMAP8, controller=ctrl, adaptive=False)
    free = pud.simulate_op("zero", [a], AMAP8, adaptive=False)
    assert r1.rows_per_channel == r2.rows_per_channel == free.rows_per_channel
    burst = max(free.rows_per_channel) * pud.PudCostModel().pud_row_ns("zero")
    # first burst pays the SB->PIM switch; the second queues behind it and
    # pays none — the makespan accumulates both bursts back to back
    assert span1 == CFG.mode_switch_ns + burst
    assert ctrl.now_ns == span1 + burst
    assert r1.t_ns - r2.t_ns == CFG.mode_switch_ns


def test_adaptive_cpu_pick_leaves_queues_untouched():
    mem = PhysicalMemory(AMAP8, seed=0, n_huge_pages=64, huge_scatter=1.0)
    alloc = PumaAllocator(mem, stripe_channels=True)
    alloc.pim_preallocate(8)
    a = alloc.pim_alloc(64)           # sub-row: CPU always wins
    ctrl = DramController(AMAP8, CFG)
    r = pud.simulate_op("zero", [a], AMAP8, controller=ctrl, adaptive=True)
    assert r.rows_per_channel is None
    assert ctrl.now_ns == 0.0
    assert all(ch.busy_until_ns == 0.0 for ch in ctrl.channels)


def test_cacheline_scheme_collapses_to_one_queue():
    """Under cacheline interleaving a region is a cross-channel stripe, so
    the channel partition degenerates to a single queue by construction."""
    amap = AddressMap(
        DramGeometry(channels=8, subarrays_per_bank=16),
        CACHELINE_INTERLEAVED_SCHEME,
    )
    rb = amap.region_bytes
    pas = np.arange(16, dtype=np.int64) * rb
    assert (amap.region_channels(pas) == 0).all()
    gsa = amap.region_subarrays(pas)
    counts = channel_row_counts(gsa, amap)
    assert counts[0] == 16 and counts[1:].sum() == 0
