"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pud_bulk import ops as pud_ops
from repro.kernels.flash_attention import ops as fl_ops
from repro.kernels.paged_attention import ops as pg_ops

RNG = np.random.default_rng(0)


# -- pud_bulk -----------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.int8])
@pytest.mark.parametrize("shape", [(8, 128), (100,), (3, 5, 7), (1000, 3)])
def test_pud_bulk_elementwise(dtype, shape):
    x = jnp.asarray(RNG.integers(0, 127, size=shape).astype(dtype))
    y = jnp.asarray(RNG.integers(0, 127, size=shape).astype(dtype))
    z = jnp.asarray(RNG.integers(0, 127, size=shape).astype(dtype))
    for fn, args in [
        (pud_ops.pud_zero, (x,)), (pud_ops.pud_copy, (x,)),
        (pud_ops.pud_not, (x,)), (pud_ops.pud_and, (x, y)),
        (pud_ops.pud_or, (x, y)), (pud_ops.pud_xor, (x, y)),
        (pud_ops.pud_maj, (x, y, z)),
    ]:
        k = fn(*args, use_kernel=True)
        r = fn(*args, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


@pytest.mark.parametrize("nb,elems,npairs", [(16, 32, 4), (8, 256, 3), (32, 48, 1)])
def test_pud_block_copy(nb, elems, npairs):
    pool = jnp.asarray(RNG.integers(0, 100, size=(nb, elems)).astype(np.int32))
    perm = RNG.permutation(nb)
    src = jnp.asarray(perm[:npairs].astype(np.int32))
    dst = jnp.asarray(perm[npairs : 2 * npairs].astype(np.int32))
    k = pud_ops.pool_block_copy(pool, src, dst, use_kernel=True)
    r = pud_ops.pool_block_copy(pool, src, dst, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Sk,D,causal,dtype",
    [
        (2, 4, 2, 64, 64, 32, True, jnp.float32),
        (1, 8, 1, 100, 100, 64, True, jnp.float32),
        (2, 4, 4, 32, 96, 80, False, jnp.float32),
        (1, 2, 2, 1, 200, 128, False, jnp.float32),
        (1, 4, 2, 128, 128, 64, True, jnp.bfloat16),
        (1, 48, 1, 33, 33, 128, True, jnp.float32),   # MQA, ragged seq
    ],
)
def test_flash_attention_matches_ref(B, Hq, Hkv, Sq, Sk, D, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, D)), dtype)
    out_k = fl_ops.flash_attention(q, k, v, causal=causal, use_kernel=True)
    out_r = fl_ops.flash_attention(q, k, v, causal=causal, use_kernel=False)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(out_k.astype(jnp.float32) - out_r.astype(jnp.float32))))
    assert err < tol, err


# -- paged attention ----------------------------------------------------------

@pytest.mark.parametrize(
    "B,Hq,Hkv,D,nb,bs,maxb",
    [(2, 8, 2, 64, 32, 16, 6), (1, 4, 4, 128, 16, 8, 4), (3, 16, 1, 32, 64, 16, 8)],
)
def test_paged_attention_matches_ref(B, Hq, Hkv, D, nb, bs, maxb):
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(nb, bs, Hkv, D)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(nb, bs, Hkv, D)), jnp.float32)
    lens = RNG.integers(1, maxb * bs, size=(B,))
    tbl = np.full((B, maxb), -1, np.int32)
    for b in range(B):
        need = -(-int(lens[b]) // bs)
        tbl[b, :need] = RNG.choice(nb, size=need, replace=False)
    tbl = jnp.asarray(tbl)
    lens = jnp.asarray(lens.astype(np.int32))
    ok = pg_ops.paged_attention(q, kp, vp, tbl, lens, use_kernel=True)
    rf = pg_ops.paged_attention(q, kp, vp, tbl, lens, use_kernel=False)
    err = float(jnp.max(jnp.abs(ok - rf)))
    assert err < 2e-5, err


def test_flash_vs_model_attention_impls():
    """naive / chunked / pallas must agree on the same inputs."""
    from repro.models.attention import _inner_attention

    B, S, H, D = 2, 65, 4, 32
    q = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    outs = {}
    for impl in ["naive", "chunked", "pallas"]:
        outs[impl] = _inner_attention(
            q, k, v, impl=impl, causal=True, kv_len=S, scale=D**-0.5
        )
    for impl in ["chunked", "pallas"]:
        err = float(jnp.max(jnp.abs(outs[impl] - outs["naive"])))
        assert err < 3e-5, (impl, err)
