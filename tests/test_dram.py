import pytest

from repro.core.dram import (
    AddressMap,
    BANK_REGION_SCHEME,
    CACHELINE_INTERLEAVED_SCHEME,
    DramGeometry,
    InterleaveScheme,
)


def test_default_geometry_matches_paper():
    geo = DramGeometry()
    # paper: 8 GB system, 1024x1024 subarray = 1 MB
    assert geo.total_bytes == 8 * 2**30
    assert geo.subarray_bytes == 2**20
    assert geo.rows_per_subarray == 1024


@pytest.mark.parametrize("scheme", [BANK_REGION_SCHEME, CACHELINE_INTERLEAVED_SCHEME])
def test_decode_fields_in_range(scheme):
    amap = AddressMap(scheme=scheme)
    geo = amap.geo
    for pa in [0, 4096, 2**20 + 512, geo.total_bytes - 1, 123456789]:
        c = amap.decode(pa)
        assert 0 <= c.channel < geo.channels
        assert 0 <= c.bank < geo.banks_per_rank
        assert 0 <= c.subarray < geo.subarrays_per_bank
        assert 0 <= c.row < geo.rows_per_subarray
        assert 0 <= c.col < geo.row_bytes


@pytest.mark.parametrize("scheme", [BANK_REGION_SCHEME, CACHELINE_INTERLEAVED_SCHEME])
def test_decode_is_bijective_over_regions(scheme):
    amap = AddressMap(scheme=scheme)
    seen = set()
    rb = amap.region_bytes
    for r in range(0, 4096):
        c = amap.decode(r * rb)
        key = (c.channel, c.rank, c.bank, c.subarray, c.row, c.col)
        assert key not in seen
        seen.add(key)


def test_region_subarray_constant_within_region_bank_scheme():
    amap = AddressMap(scheme=BANK_REGION_SCHEME)
    rb = amap.region_bytes
    for base in [0, rb * 7, rb * 1023, rb * 5000]:
        ids = {
            amap.decode(base + off).global_subarray(amap.geo)
            for off in range(0, rb, 97)
        }
        assert len(ids) == 1


def test_regions_in_range_alignment():
    amap = AddressMap()
    rb = amap.region_bytes
    regions = amap.regions_in_range(rb // 2, 10 * rb)
    # first partial region excluded; all returned PAs aligned
    assert all(pa % rb == 0 for pa, _ in regions)
    assert len(regions) == 9


def test_xor_scheme_decodes():
    scheme = InterleaveScheme(
        order=CACHELINE_INTERLEAVED_SCHEME.order, xor_row_into_bank=True
    )
    amap = AddressMap(scheme=scheme)
    # still bijective at region granularity
    ids = {amap.region_subarray(r * amap.region_bytes) for r in range(2048)}
    assert len(ids) > 1


def test_non_pow2_geometry_rejected():
    with pytest.raises(ValueError):
        DramGeometry(channels=3)
