"""Serving load-harness suite (ISSUE 9): scenarios through the real engine.

Marked ``serve`` — the CI gate runs this suite plus the fixed-seed
``benchmarks/serve_bench.py --smoke --gate`` pass.  Three anchors:

* **Conservation** — after an open-loop run drains, every submitted request
  is accounted for: ``submitted == done + rejected + cancelled`` and the
  pool is back to fully free.
* **Determinism** — the same seeded scenario through two fresh engines
  yields the *same metrics record*, byte for byte.
* **Schema** — the key names/types of ``ServeEngine.metrics()``,
  ``channel_occupancy()``, ``stall_report()`` and ``step_sample()`` are
  pinned, because ``BENCH_serve.json`` and the CI gate read them by name.
"""
import json

import jax
import pytest

from repro.configs.registry import get_config
from repro.core.kv_pool import KVPoolConfig
from repro.models.transformer import LM
from repro.robustness import check_engine
from repro.serve.engine import MaintenanceConfig, Request, ServeEngine
from repro.serve.loadgen import build_scenario, play

pytestmark = pytest.mark.serve


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm_1_6b").smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    return model, params


def _engine(model_and_params, overrides=()):
    model, params = model_and_params
    cfg = model.cfg
    base = dict(
        num_blocks=32, block_size=8, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        n_layers=cfg.n_layers, max_seqs=4, max_blocks_per_seq=16,
        blocks_per_arena=16, policy="puma", dtype="float32",
    )
    base.update(dict(overrides))
    return ServeEngine(
        model, params, KVPoolConfig(**base),
        use_kernel=False, maintenance=MaintenanceConfig(),
    )


def _run_scenario(model_and_params, name):
    sc = build_scenario(name, smoke=True)
    eng = _engine(model_and_params, sc.pool)
    rec = play(eng, sc.generate(), max_steps=sc.max_steps)
    return eng, rec


# ---------------------------------------------------------------------------
# conservation + sanity under load
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["bursty", "cancel_heavy"])
def test_open_loop_run_conserves_the_request_ledger(model_and_params, name):
    eng, rec = _run_scenario(model_and_params, name)
    assert rec["conservation_ok"]
    assert rec["submitted"] == rec["n"]
    assert rec["submitted"] == rec["done"] + rec["rejected"] + rec["cancelled"]
    assert not eng.queue and not eng.live
    assert eng.pool.pool.free_tiles() == eng.pool.pool.total_tiles
    check_engine(eng).assert_ok()


def test_bursty_scenario_exercises_preemption_and_recompute(model_and_params):
    eng, rec = _run_scenario(model_and_params, "bursty")
    assert rec["preemptions"] > 0
    assert rec["done"] == rec["n"]          # recompute-on-resume finished all
    assert rec["queue_depth_peak"] > 0      # open loop measured the herd


def test_cancel_heavy_scenario_actually_cancels(model_and_params):
    _, rec = _run_scenario(model_and_params, "cancel_heavy")
    assert rec["cancelled"] > 0
    assert rec["done"] > 0                  # but not everything dies


def test_metric_record_sanity(model_and_params):
    _, rec = _run_scenario(model_and_params, "steady")
    assert rec["tokens"] > 0 and rec["tokens_per_s"] > 0
    assert 0.0 <= rec["occupancy_mean"] <= rec["occupancy_peak"] <= 1.0
    assert 0.0 < rec["contiguity_min"] <= rec["contiguity"] <= 1.0
    assert rec["p50_queue_steps"] <= rec["p99_queue_steps"]
    assert rec["p50_complete_steps"] <= rec["p99_complete_steps"]
    assert rec["sim_ns"] > 0
    json.dumps(rec)                          # the whole record is JSON-clean


def test_fixed_seed_scenario_is_deterministic(model_and_params):
    _, a = _run_scenario(model_and_params, "steady")
    _, b = _run_scenario(model_and_params, "steady")
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_client_cancel_mid_decode_releases_the_slot(model_and_params):
    eng = _engine(model_and_params)
    eng.submit(Request(rid=0, prompt=[1, 2, 3, 4], max_new=6))
    eng.step()                               # prefill + first decode
    assert eng.cancel(0)
    assert not eng.live and len(eng.cancelled) == 1
    assert eng.cancel(0) is False            # idempotent: already finished
    assert eng.pool.pool.free_tiles() == eng.pool.pool.total_tiles
    eng.drain()
    assert eng.submitted == 1 and len(eng.cancelled) == 1


# ---------------------------------------------------------------------------
# schema pins (satellite: BENCH_serve.json + the CI gate read these by name)
# ---------------------------------------------------------------------------

def _loaded_engine(model_and_params):
    eng = _engine(model_and_params)
    eng.submit(Request(rid=0, prompt=[5, 6, 7], max_new=3))
    eng.step()
    return eng


METRICS_KEYS = {
    "mean_contiguous_fraction", "descriptors_per_tile", "live_seqs",
    "channels", "channel_balance", "clock", "steps", "tokens",
    "tokens_prefilled", "submitted", "done", "queue_depth", "used_fraction",
    "frag", "align_hits", "align_misses", "rejected", "cancelled",
    "preemptions", "injected_misses", "maintenance_ns", "compaction_passes",
    "blocks_migrated",
}

STEP_SAMPLE_KEYS = {
    "contiguity", "descriptors_per_tile", "channel_balance", "clock",
    "steps", "live", "queued", "free_tiles", "used_fraction",
    "tokens_decoded", "tokens_prefilled", "done", "rejected", "cancelled",
    "preemptions",
}

STALL_REPORT_KEYS = {
    "clock", "steps", "queued", "live", "free_tiles", "total_tiles",
    "free_slots", "done", "rejected", "cancelled", "preemptions",
}


def test_metrics_schema_is_pinned(model_and_params):
    met = _loaded_engine(model_and_params).metrics()
    assert set(met) == METRICS_KEYS
    assert all(isinstance(v, float) for v in met.values()), {
        k: type(v) for k, v in met.items() if not isinstance(v, float)
    }


def test_step_sample_schema_is_pinned(model_and_params):
    sample = _loaded_engine(model_and_params).step_sample()
    assert set(sample) == STEP_SAMPLE_KEYS
    assert all(isinstance(v, float) for v in sample.values())


def test_stall_report_schema_is_pinned(model_and_params):
    eng = _engine(model_and_params)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new=2))
    rep = eng.stall_report()
    assert set(rep) == STALL_REPORT_KEYS
    assert isinstance(rep["queued"], list)
    assert set(rep["queued"][0]) == {"rid", "blocks_needed", "preemptions"}
    for k in STALL_REPORT_KEYS - {"queued"}:
        assert isinstance(rep[k], int), k


def test_channel_occupancy_schema_is_pinned(model_and_params):
    eng = _loaded_engine(model_and_params)
    occ = eng.channel_occupancy()
    assert set(occ) == {"channels", "used_tiles", "free_tiles", "balance"}
    assert isinstance(occ["channels"], int)
    assert isinstance(occ["balance"], float)
    assert len(occ["used_tiles"]) == len(occ["free_tiles"]) == occ["channels"]
    assert sum(occ["used_tiles"]) > 0        # one live sequence holds tiles


# ---------------------------------------------------------------------------
# opt-in full-size lane (scripts/ci.sh --full): the production-scale
# trajectory, not the smoke shrink
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("name", ["steady", "bursty"])
def test_full_size_scenario_trajectory(model_and_params, name):
    """Full (non-smoke) scenario through the engine: hundreds of requests
    per scenario (the whole registry streams ~1800 across the five), with
    the same ledger-conservation and drain invariants as the smoke lane."""
    sc = build_scenario(name, smoke=False)
    eng = _engine(model_and_params, sc.pool)
    rec = play(eng, sc.generate(), max_steps=sc.max_steps)
    assert rec["n"] >= 10 * build_scenario(name, smoke=True).generate().__len__()
    assert rec["conservation_ok"]
    assert rec["submitted"] == rec["done"] + rec["rejected"] + rec["cancelled"]
    assert not eng.queue and not eng.live
    assert eng.pool.pool.free_tiles() == eng.pool.pool.total_tiles
    assert rec["tokens_per_s"] > 0
