"""Trace replay + GEMV offload property suite (ISSUE 10).

Anchors:

* **Replay == live** — a scenario recorded through the live engine
  re-prices through :func:`repro.trace.replay.replay_trace` bit-exactly,
  and the replayed SimCost total equals the one the load harness computed
  from the engine's own counters.
* **GEMV functional equivalence** — partitioned in-DRAM/CPU dispatch of
  ``W @ x`` is bit-exact against a whole-matrix ``jnp.dot`` under all four
  allocator placements (integer-valued float32, so accumulation order
  cannot introduce ULP noise).
* **Allocator story** — PUD-offloaded decode fraction is 0 for
  malloc/posix_memalign, partial for hugepage, ~1 and strictly highest
  for PUMA; the adaptive driver is never slower than CPU-only decode.
* **Canonical serialization** — parse -> serialize is the identity, the
  property that makes byte-identity a meaningful golden check.
"""
import json

import numpy as np
import pytest

pytestmark = pytest.mark.trace


@pytest.fixture(scope="module")
def recorded_run():
    from repro.trace.serve_trace import record_scenario

    return record_scenario("steady", smoke=True, n_requests=8)


def test_mac_op_pinned():
    """The MAC extension's planning/pricing constants are load-bearing
    (2 operands keeps the hugepage fraction partial; 8 AAPs prices it)."""
    from repro.core.pud import N_OPERANDS, PUD_AAPS, PudCostModel

    assert N_OPERANDS["mac"] == 2
    assert PUD_AAPS["mac"] == 8
    assert PudCostModel().pud_row_ns("mac") == 8 * 90.0 + 20.0


def test_live_trace_replays_bit_exact(recorded_run):
    from repro.trace.replay import parse_trace, replay_trace

    trace, rec = recorded_run
    res = replay_trace(parse_trace(trace.to_jsonl()))
    assert res.ok, res.report()
    # the replayed SimCost total is the load harness's, to its rounding
    assert round(res.recomputed["sim_ns"], 3) == rec["sim_ns"]
    assert res.totals["tokens_decoded"] == rec["tokens"]
    assert res.totals["tokens_prefilled"] == rec["tokens_prefilled"]
    assert res.totals["clock"] == rec["clock"]
    assert res.totals["maintenance_ns"] == rec["maintenance_ns"]


def test_trace_serialization_roundtrip(recorded_run):
    trace, _ = recorded_run
    text = trace.to_jsonl()
    lines = text.splitlines()
    assert len(lines) == len(trace.events)
    for line, ev in zip(lines, trace.events):
        assert json.loads(line) == ev
        assert json.dumps(
            json.loads(line), sort_keys=True, separators=(",", ":")
        ) == line


@pytest.mark.parametrize(
    "allocator", ["malloc", "posix_memalign", "hugepage", "puma"]
)
def test_gemv_bit_exact_under_every_placement(allocator):
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.core.allocators import PhysicalMemory
    from repro.core.dram import AddressMap
    from repro.trace.gemv import build_placement, gemv_execute, weight_shapes

    cfg = get_config("stablelm_1_6b").smoke()
    shapes = weight_shapes(cfg)
    amap = AddressMap()
    mem = PhysicalMemory(amap, seed=3)
    placement = build_placement(shapes, allocator, mem)
    rng = np.random.default_rng(7)
    for name in ("L0/attn/wq", "L1/mlp/w_out", "lm_head"):
        n_out, d_in = shapes[name]
        w = rng.integers(-8, 8, size=(n_out, d_in)).astype(np.float32)
        x = rng.integers(-8, 8, size=(d_in,)).astype(np.float32)
        w_alloc, acc_alloc = placement[name]
        y = gemv_execute(w, x, w_alloc, acc_alloc, amap)
        ref = np.asarray(jnp.dot(jnp.asarray(w), jnp.asarray(x)))
        assert np.array_equal(y, ref), (allocator, name)


def test_offload_fractions_tell_the_paper_story():
    from repro.trace.gemv import ALLOCATORS, offload_report

    reports = {
        al: offload_report("stablelm_1_6b", al, n_tokens=1)
        for al in ALLOCATORS
    }
    frac = {al: r["offload_fraction"] for al, r in reports.items()}
    assert frac["malloc"] == 0.0
    assert frac["posix_memalign"] == 0.0
    assert 0.0 < frac["hugepage"] < 0.95
    assert frac["puma"] >= 0.99
    assert all(frac["puma"] > frac[al] for al in
               ("malloc", "posix_memalign", "hugepage"))
    # adaptive driver: CPU-bound placements price at exactly CPU speed
    assert reports["malloc"]["speedup_vs_cpu"] == 1.0
    assert reports["posix_memalign"]["speedup_vs_cpu"] == 1.0
    assert reports["hugepage"]["speedup_vs_cpu"] >= 1.0
    assert reports["puma"]["speedup_vs_cpu"] >= 1.5


def test_moe_routing_deterministic_and_routed():
    from repro.configs.registry import get_config, moe_archs
    from repro.trace.gemv import decode_op_stream

    assert "granite_moe_1b_a400m" in moe_archs()
    cfg = get_config("granite_moe_1b_a400m").smoke()
    a = decode_op_stream(cfg, seed=11, n_tokens=3)
    b = decode_op_stream(cfg, seed=11, n_tokens=3)
    assert a == b
    assert a != decode_op_stream(cfg, seed=12, n_tokens=3)
    experts = {op.split("/")[2] for op in a if "/moe/e" in op}
    assert len(experts) >= 2, "routing never varied the expert set"
    per_layer_tok = cfg.experts_per_tok * 3  # w_in/w_gate/w_out
    moe_l0 = [op for op in a if op.startswith("L0/moe/e")]
    assert len(moe_l0) == per_layer_tok * 3  # 3 tokens


def test_gemv_pud_op_trace_replays(tmp_path):
    """pud_op events (incl. the controller-dispatched channel arm) replay
    bit-exactly from the JSONL alone."""
    from repro.trace.gemv import channel_study, offload_report
    from repro.trace.record import TraceRecorder
    from repro.trace.replay import parse_trace, replay_trace

    rec = TraceRecorder(channels=1, meta={"what": "gemv"})
    offload_report("stablelm_1_6b", "hugepage", n_tokens=1, recorder=rec)
    rec.finalize(clock=0, tokens_decoded=0, tokens_prefilled=0,
                 maintenance_ns=0.0)
    res = replay_trace(parse_trace(rec.to_jsonl()))
    assert res.ok, res.report()

    rec2 = TraceRecorder(channels=4, meta={"what": "channel"})
    report = channel_study("stablelm_1_6b", recorder=rec2)
    rec2.finalize(clock=0, tokens_decoded=0, tokens_prefilled=0,
                  maintenance_ns=0.0)
    res2 = replay_trace(parse_trace(rec2.to_jsonl()))
    assert res2.ok, res2.report()
    assert report["parallel_speedup"] >= 2.0
    path = tmp_path / "gemv.trace.jsonl"
    rec2.write(str(path))
    assert replay_trace(path.read_text()).ok
