"""Per-arch smoke tests (reduced configs) + decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunShape
from repro.configs.registry import get_config, lm_archs
from repro.launch.inputs import make_batch
from repro.models.transformer import LM

TRAIN = RunShape("smoke_train", 32, 2, "train")


@pytest.mark.parametrize("arch", lm_archs())
def test_arch_smoke_train_step(arch):
    """One forward/train step on CPU: output shapes + no NaNs (assignment)."""
    cfg = get_config(arch).smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, TRAIN)
    loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0, arch


@pytest.mark.parametrize("arch", lm_archs())
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(2, 32, enc_len=32 if cfg.is_encdec else 0)
    db = {
        "tokens": jnp.zeros((2, 1), jnp.int32),
        "positions": jnp.zeros(
            (2, 1, 3) if cfg.rope == "mrope" else (2, 1), jnp.int32
        ),
    }
    logits, cache2 = model.decode_step(params, db, cache)
    assert logits.shape[0] == 2
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    # split caches count appends in len_rec; recurrent caches in len
    total = int(cache2["len"]) + int(cache2.get("len_rec", 0))
    assert total == 1


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "chatglm3_6b", "rwkv6_7b", "zamba2_7b"])
def test_decode_matches_teacher_forcing(arch):
    """Token-by-token decode logits == full-sequence forward logits."""
    cfg = get_config(arch).smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(1))
    S = 9
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, S)), jnp.int32
    )
    pos = jnp.arange(S, dtype=jnp.int32)[None]

    # teacher-forced: logits at the last position
    batch = {"tokens": toks, "positions": pos}
    full = model.prefill_logits(params, batch)

    # incremental decode
    cache = model.init_cache(1, S + 1)
    logits = None
    for t in range(S):
        db = {"tokens": toks[:, t : t + 1], "positions": pos[:, t : t + 1]}
        logits, cache = model.decode_step(params, db, cache)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full), rtol=2e-4, atol=2e-4
    )


def test_scan_equals_unrolled():
    cfg = get_config("mistral_nemo_12b").smoke()
    batch = make_batch(cfg, TRAIN)
    losses = []
    for scan in (True, False):
        model = LM(cfg, attn_impl="naive", remat=None, scan_layers=scan)
        params = model.init(jax.random.key(0))
        losses.append(float(model.train_loss(params, batch)))
    assert abs(losses[0] - losses[1]) < 1e-5


def test_attn_impls_agree_end_to_end():
    cfg = get_config("stablelm_1_6b").smoke()
    batch = make_batch(cfg, TRAIN)
    vals = []
    for impl in ("naive", "chunked"):
        model = LM(cfg, attn_impl=impl, remat=None)
        params = model.init(jax.random.key(0))
        vals.append(float(model.train_loss(params, batch)))
    assert abs(vals[0] - vals[1]) < 1e-4


def test_vlm_patch_embeds_change_output():
    cfg = get_config("qwen2_vl_72b").smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, TRAIN)
    l1 = float(model.train_loss(params, batch))
    batch2 = dict(batch)
    batch2["patch_embeds"] = batch["patch_embeds"] + 1.0
    l2 = float(model.train_loss(params, batch2))
    assert l1 != l2


def test_param_counts_close_to_analytic():
    from repro.models.params import count_params

    for arch in ["stablelm_1_6b", "mistral_nemo_12b"]:
        cfg = get_config(arch)
        model = LM(cfg)
        defs = model.param_defs()
        from repro.models.params import ParamDef
        total = 0
        for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef)):
            n = 1
            for s in d.shape:
                n *= s
            total += n
        analytic = cfg.n_params()
        # within 15% (vocab padding, norm params, analytic approximations)
        assert abs(total - analytic) / analytic < 0.15, (arch, total, analytic)
