"""Golden-trace regression suite (ISSUE 10): the recorded serving trace
of the fixed-seed ``steady`` smoke scenario is pinned byte-for-byte under
``tests/goldens/``.

Any drift in the trace schema, the event stream, or the pricing
arithmetic (controller timings, RowClone/CPU split, SimCost totals) makes
the regeneration differ from the golden — and the failure is *loud*: a
unified diff of the JSONL, not just a boolean.  Deliberate changes must
bump :data:`repro.trace.record.SCHEMA_VERSION` and regenerate via::

    PYTHONPATH=src python -m repro.trace.serve_trace \
        --write-golden tests/goldens/steady_smoke.trace.jsonl
"""
import difflib
import json
import pathlib

import pytest

pytestmark = pytest.mark.trace

GOLDEN = pathlib.Path(__file__).parent / "goldens" / "steady_smoke.trace.jsonl"


@pytest.fixture(scope="module")
def golden_text() -> str:
    return GOLDEN.read_text()


@pytest.fixture(scope="module")
def regenerated():
    from repro.trace.serve_trace import record_scenario

    trace, rec = record_scenario("steady", smoke=True)
    return trace, rec


def test_golden_regenerates_byte_identical(golden_text, regenerated):
    """The whole point: same seeds -> same bytes, across runs and machines."""
    trace, _ = regenerated
    got = trace.to_jsonl()
    if got == golden_text:
        return
    diff = "\n".join(difflib.unified_diff(
        golden_text.splitlines(), got.splitlines(),
        fromfile="tests/goldens/steady_smoke.trace.jsonl",
        tofile="regenerated(steady, smoke)", lineterm="", n=2,
    ))
    pytest.fail(
        "regenerated steady-smoke trace drifted from the golden.\n"
        "If the change is deliberate, bump SCHEMA_VERSION and rewrite the\n"
        "golden via `python -m repro.trace.serve_trace --write-golden ...`.\n"
        + diff
    )


def test_golden_header_pins_schema_and_constants(golden_text):
    """Header carries everything replay needs; constants are the repo's."""
    from repro.trace.record import SCHEMA_VERSION

    header = json.loads(golden_text.splitlines()[0])
    assert header["kind"] == "header"
    assert header["schema"] == SCHEMA_VERSION == 1
    assert header["model"] == {
        "aap_ns": 90.0, "pud_issue_ns": 20.0, "cpu_bw_gbs": 10.0,
        "cpu_op_overhead_ns": 250.0, "cpu_row_touch_ns": 40.0,
    }
    assert header["ctrl"] == {
        "mode_switch_ns": 120.0, "row_hit_ns": 15.0, "row_miss_ns": 50.0,
        "cacheline_bytes": 64,
    }
    assert header["sim"] == {
        "step_overhead_ns": 2000.0, "decode_token_ns": 500.0,
        "prefill_token_ns": 150.0,
    }
    assert header["meta"]["scenario"] == "steady"
    assert header["meta"]["seed"] == 901


def test_golden_replays_bit_exact(golden_text):
    from repro.trace.replay import parse_trace, replay_trace

    res = replay_trace(parse_trace(golden_text))
    assert res.ok, res.report()
    assert res.totals is not None and res.totals["sim_ns"] > 0
    assert res.recomputed["sim_ns"] == res.totals["sim_ns"]


def test_schema_mismatch_refused(golden_text):
    """A foreign-schema trace is rejected up front, with regeneration
    guidance — not silently replayed against the wrong arithmetic."""
    from repro.trace.record import TraceSchemaError
    from repro.trace.replay import parse_trace

    lines = golden_text.splitlines()
    header = json.loads(lines[0])
    header["schema"] = 999
    tampered = "\n".join(
        [json.dumps(header, sort_keys=True, separators=(",", ":"))]
        + lines[1:]
    )
    with pytest.raises(TraceSchemaError, match="999"):
        parse_trace(tampered)


def test_pricing_drift_fails_loud(golden_text):
    """Tampering one priced field makes replay fail and name the event."""
    from repro.trace.replay import parse_trace, replay_trace

    events = parse_trace(golden_text)
    victim = next(e for e in events if e["kind"] == "prefill")
    victim["done"] = victim["done"] + 1.0
    res = replay_trace(events)
    assert not res.ok
    assert any(
        f"event {victim['i']} (prefill): done" in m for m in res.mismatches
    ), res.report()
    assert "replay FAILED" in res.report()
