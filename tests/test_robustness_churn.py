"""Long-horizon alloc/free churn under fault injection (ISSUE 7).

10k-cycle randomized churn on :class:`PumaAllocator` and :class:`TilePool`,
with the invariant auditors running periodically: no region/tile overlap, no
double-free, and total_free conserved — under both interleave schemes and
with striped and unstriped channels, with a low-rate fault injector running
the whole time.
"""
import random

import numpy as np
import pytest

from repro.core.allocators import PhysicalMemory
from repro.core.arena import TilePool
from repro.core.dram import (
    AddressMap,
    BANK_REGION_SCHEME,
    CACHELINE_INTERLEAVED_SCHEME,
    DramGeometry,
)
from repro.core.puma import PumaAllocator
from repro.robustness import (
    DoubleFree,
    FaultInjector,
    FaultPlan,
    check_allocator,
    check_tile_pool,
)

pytestmark = pytest.mark.chaos


def hyp_seeds(func):
    """Drive ``func(..., seed=...)`` with hypothesis when installed; fall
    back to fixed seeds otherwise — the churn must run either way (the
    container may not ship hypothesis, and these are the chaos-suite
    invariant drivers)."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        return pytest.mark.parametrize("seed", [0xC0FFEE, 0xBADF00D])(func)
    return settings(max_examples=2, deadline=None)(
        given(seed=st.integers(0, 2**32 - 1))(func)
    )


GEO = DramGeometry(channels=4, subarrays_per_bank=4)
SCHEMES = {
    "bank_region": BANK_REGION_SCHEME,
    "cacheline": CACHELINE_INTERLEAVED_SCHEME,
}
CYCLES = 10_000
AUDIT_EVERY = 1_000


@pytest.mark.parametrize("scheme", sorted(SCHEMES))
@pytest.mark.parametrize("striped", [False, True], ids=["unstriped", "striped"])
@hyp_seeds
def test_puma_allocator_survives_churn(scheme, striped, seed):
    amap = AddressMap(GEO, SCHEMES[scheme])
    region = amap.region_bytes
    inj = FaultInjector(FaultPlan(seed=seed, alloc_miss_rate=0.02))
    mem = PhysicalMemory(amap, n_huge_pages=24, seed=seed % 13, injector=inj)
    pa = PumaAllocator(mem, stripe_channels=striped, injector=inj)
    pa.pim_preallocate(12)
    total = pa.free_regions()

    rng = random.Random(seed)
    live = []
    for cycle in range(CYCLES):
        roll = rng.random()
        if roll < 0.45 or not live:
            a = pa.pim_alloc(rng.randint(1, 4 * region))
            if a is not None:
                live.append(a)
        elif roll < 0.60 and live:
            hint = rng.choice(live)
            a = pa.pim_alloc_align(rng.randint(1, 3 * region), hint)
            if a is not None:
                live.append(a)
        else:
            victim = live.pop(rng.randrange(len(live)))
            pa.pim_free(victim)
            with pytest.raises(DoubleFree):
                pa.pim_free(victim)         # double-free is always rejected
        if cycle % AUDIT_EVERY == AUDIT_EVERY - 1:
            check_allocator(pa).assert_ok()

    # no overlap across everything still live
    seen = set()
    for a in live:
        for e in a.extents:
            assert e.pa not in seen
            seen.add(e.pa)
    # conservation: every region is free or backs a live allocation
    used = sum(-(-a.size // region) for a in live)
    assert pa.free_regions() + used == total
    for a in live:
        pa.pim_free(a)
    assert pa.free_regions() == total
    check_allocator(pa).assert_ok()


@pytest.mark.parametrize("n_channels", [1, 4], ids=["unstriped", "striped"])
@hyp_seeds
def test_tile_pool_survives_churn(n_channels, seed):
    inj = FaultInjector(FaultPlan(seed=seed, alloc_miss_rate=0.02))
    pool = TilePool(16, 32, "puma", n_channels=n_channels, injector=inj)
    total = pool.total_tiles

    rng = random.Random(seed)
    live = []
    for cycle in range(CYCLES):
        roll = rng.random()
        if roll < 0.40 or not live:
            h = pool.alloc(rng.randint(1, 12))
            if h is not None:
                live.append(h)
        elif roll < 0.55:
            h = pool.alloc_align(rng.randint(1, 8), rng.choice(live))
            if h is not None:
                live.append(h)
        elif roll < 0.70:
            pool.extend(rng.choice(live), 1)
        else:
            victim = live.pop(rng.randrange(len(live)))
            pool.free(victim)
            with pytest.raises(DoubleFree):
                pool.free(victim)
        if cycle % AUDIT_EVERY == AUDIT_EVERY - 1:
            check_tile_pool(pool).assert_ok()

    owned = [t for h in live for t in h.tiles]
    assert len(set(owned)) == len(owned)            # no overlap
    assert pool.free_tiles() + len(owned) == total  # conservation
    for h in live:
        pool.free(h)
    assert pool.free_tiles() == total
    check_tile_pool(pool).assert_ok()
