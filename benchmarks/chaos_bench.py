"""Chaos benchmark (ISSUE 7): degraded-mode metrics under injected faults.

Drives the stack with the documented chaos-suite fault plan —

  * RowClone row-failure rate **1e-3** (paper-scale transient AAP faults),
  * huge-page-pool exhaustion / transient allocation-miss rate **10 %**,
  * **one blacklisted subarray** (permanent manufacturing fault),
  * 1 % controller stalls (refresh storms),

all from one fixed seed, and persists ``BENCH_faults.json``:

* ``alloc/clean`` vs ``alloc/faulty`` — allocation churn through
  :class:`~repro.core.puma.RobustAllocator`: every request must be served
  (the fallback chain absorbs the faults); records fallback fraction,
  retries, refills, and simulated backoff.
* ``pud/<op>/degraded`` — simulated PUD latency with mid-flight RowClone
  faults vs fault-free (``speedup`` = clean/degraded <= 1: the honest
  degradation factor).
* ``serve/clean`` vs ``serve/faulty`` — the hardened engine on a tight KV
  pool: p50/p99 completion latency (engine steps), preemptions, and the
  zero-silent-drop ledger (done + rejected + cancelled == submitted).
* ``determinism`` — the faulty allocation section re-run from the same
  seed must reproduce its stats bit-for-bit (the CI chaos gate).

``run(emit)`` plugs into ``benchmarks/run.py``; ``main()`` (``--smoke``)
persists the JSON.
"""
from __future__ import annotations

import json
import random
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import pud
from repro.core.allocators import PhysicalMemory
from repro.core.dram import AddressMap, DramGeometry
from repro.core.puma import PumaAllocator, RobustAllocator
from repro.robustness import FaultInjector, FaultPlan, check_allocator

OUT_PATH = "BENCH_faults.json"

#: fixed seed: the whole benchmark is reproducible bit-for-bit, which the
#: CI gate asserts.
CHAOS_SEED = 1234

AMAP = AddressMap()
REGION = AMAP.region_bytes
# churn geometry: 1 MB subarrays (128 rows), so blacklisting one subarray
# quarantines *part* of the pool rather than all of it (a default-geometry
# subarray is 8 MB and would swallow the whole 4 MB PUD pool).
CHURN_AMAP = AddressMap(DramGeometry(rows_per_subarray=128))


def _churn_mem(injector=None) -> PhysicalMemory:
    return PhysicalMemory(CHURN_AMAP, n_huge_pages=5, seed=0,
                          injector=injector)


def _covered_subarray() -> int:
    """A subarray the churn's PUD pool actually covers, probed fault-free
    (fixed memory seed, so deterministic) — blacklisting it guarantees the
    boot quarantine has something to quarantine."""
    pa = PumaAllocator(_churn_mem())
    pa.pim_preallocate(2)
    a = pa.pim_alloc(REGION)
    return int(CHURN_AMAP.region_subarray(a.extents[0].pa))


def chaos_plan() -> FaultPlan:
    """The documented chaos-suite fault plan."""
    return FaultPlan(
        seed=CHAOS_SEED,
        rowclone_fail_rate=1e-3,
        huge_exhaust_rate=0.10,
        alloc_miss_rate=0.10,
        channel_stall_rate=0.01,
        blacklist_subarrays=(_covered_subarray(),),
    )


# ---------------------------------------------------------------------------
# allocation churn through the fallback chain
# ---------------------------------------------------------------------------

def _churn_alloc(n_ops: int, injector: Optional[FaultInjector]) -> Dict:
    # deliberately tight: 5 huge pages total, 2 preallocated to the PUD
    # pool, so sustained churn drains tier 1 and exercises the full
    # PUMA -> huge -> base fallback chain (base pages never run out here).
    pa = PumaAllocator(_churn_mem(injector), injector=injector)
    pa.pim_preallocate(2)
    ra = RobustAllocator(pa)
    rng = random.Random(CHAOS_SEED)
    live: List = []
    t0 = time.perf_counter()
    for _ in range(n_ops):
        if live and rng.random() < 0.35:
            ra.free(live.pop(rng.randrange(len(live))))
        else:
            live.append(ra.alloc(rng.randint(1, 64) * REGION))
    seconds = time.perf_counter() - t0
    check_allocator(pa).assert_ok()
    for a in live:
        ra.free(a)
    st = ra.stats
    return {
        "n": n_ops,
        "seconds": seconds,
        "served": st.served,
        "fallback_fraction": st.fallback_fraction(),
        "tiers": {"puma": st.puma, "huge": st.huge, "base": st.base},
        "retries": st.retries,
        "refills": st.refills,
        "backoff_ns": st.backoff_ns,
        "quarantined_regions": pa.quarantined_regions(),
        "injected": injector.stats.as_dict() if injector else None,
    }


# ---------------------------------------------------------------------------
# PUD latency under RowClone faults
# ---------------------------------------------------------------------------

def _pud_degradation(op: str, n_rows: int, n_ops: int) -> Dict:
    size = n_rows * REGION

    def operands(injector=None):
        mem = PhysicalMemory(AMAP, n_huge_pages=64, seed=1)
        pa = PumaAllocator(mem, injector=injector)
        pa.pim_preallocate(32)
        ops = [pa.pim_alloc(size)]
        while len(ops) < pud.N_OPERANDS[op]:
            ops.append(pa.pim_alloc_align(size, ops[0]))
        return ops

    clean_ops = operands()
    t_clean = sum(
        pud.simulate_op(op, clean_ops, AMAP).t_ns for _ in range(n_ops)
    )
    inj = FaultInjector(FaultPlan(seed=CHAOS_SEED,
                                  rowclone_fail_rate=1e-3))
    faulty_ops = operands(injector=inj)
    results = [
        pud.simulate_op(op, faulty_ops, AMAP, injector=inj)
        for _ in range(n_ops)
    ]
    t_faulty = sum(r.t_ns for r in results)
    return {
        "n": n_ops,
        "rows_per_op": n_rows,
        "clean_ns": t_clean,
        "degraded_ns": t_faulty,
        "speedup": t_clean / t_faulty,          # <= 1: degradation factor
        "faulted_rows": sum(r.faulted_rows for r in results),
        "injected": inj.stats.as_dict(),
    }


# ---------------------------------------------------------------------------
# hardened serving under faults
# ---------------------------------------------------------------------------

def _serve(n_requests: int, max_new: int, injector: Optional[FaultInjector]) -> Dict:
    import jax

    from repro.configs.registry import get_config
    from repro.core.kv_pool import KVPoolConfig
    from repro.models.transformer import LM
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("stablelm_1_6b").smoke()
    model = LM(cfg, attn_impl="naive", remat=None)
    params = model.init(jax.random.key(0))
    pool_cfg = KVPoolConfig(
        num_blocks=8, block_size=4, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        n_layers=cfg.n_layers, max_seqs=2, max_blocks_per_seq=8,
        blocks_per_arena=8, policy="puma", dtype="float32",
    )
    eng = ServeEngine(model, params, pool_cfg, use_kernel=False,
                      injector=injector)
    rng = np.random.default_rng(CHAOS_SEED)
    for i in range(n_requests):
        eng.submit(Request(rid=i, prompt=list(rng.integers(0, 64, 10)),
                           max_new=max_new))
    latencies: Dict[int, int] = {}
    t0 = time.perf_counter()
    seen = 0
    for _ in range(1000):
        alive = eng.step()
        for r in eng.done[seen:]:
            latencies[r.rid] = eng.clock - r.submit_clock
        seen = len(eng.done)
        if not alive:
            break
    seconds = time.perf_counter() - t0
    lats = sorted(latencies.values())
    return {
        "n": n_requests,
        "seconds": seconds,
        "done": len(eng.done),
        "rejected": len(eng.rejected),
        "cancelled": len(eng.cancelled),
        "submitted": eng.submitted,
        "tokens": eng.tokens_decoded,
        "preemptions": eng.preemptions,
        "injected_misses": eng.pool.pool.stats.injected_misses,
        "p50_steps": float(np.percentile(lats, 50)) if lats else None,
        "p99_steps": float(np.percentile(lats, 99)) if lats else None,
    }


# ---------------------------------------------------------------------------

def bench(smoke: bool = False) -> Dict:
    n_alloc = 150 if smoke else 600
    n_pud = 20 if smoke else 100
    pud_rows = 128 if smoke else 512
    n_req = 4 if smoke else 8
    # 20-token sequences on a 32-token pool collide -> preemption; in smoke
    # mode stay short (each new prefill length is a fresh XLA compile).
    max_new = 6 if smoke else 10
    plan = chaos_plan()

    results: Dict[str, Dict] = {}
    results["alloc/clean"] = _churn_alloc(n_alloc, None)
    faulty = _churn_alloc(n_alloc, FaultInjector(plan))
    faulty["speedup"] = results["alloc/clean"]["seconds"] / faulty["seconds"]
    results["alloc/faulty"] = faulty

    # bit-for-bit reproducibility of the whole faulty section (fixed seed)
    replay = _churn_alloc(n_alloc, FaultInjector(plan))
    drop = ("seconds", "speedup")   # wall time is the only non-determinism
    results["determinism"] = {
        "n": n_alloc,
        "identical": {k: v for k, v in faulty.items() if k not in drop}
        == {k: v for k, v in replay.items() if k not in drop},
    }

    for op in ("copy", "and"):
        results[f"pud/{op}/degraded"] = _pud_degradation(op, pud_rows, n_pud)

    results["serve/clean"] = _serve(n_req, max_new, None)
    serve_faulty = _serve(
        n_req, max_new,
        FaultInjector(FaultPlan(seed=CHAOS_SEED, alloc_miss_rate=0.10)),
    )
    clean_p99 = results["serve/clean"]["p99_steps"]
    if clean_p99 and serve_faulty["p99_steps"]:
        serve_faulty["speedup"] = clean_p99 / serve_faulty["p99_steps"]
    results["serve/faulty"] = serve_faulty

    results["config"] = {
        "seed": CHAOS_SEED,
        "rowclone_fail_rate": plan.rowclone_fail_rate,
        "huge_exhaust_rate": plan.huge_exhaust_rate,
        "alloc_miss_rate": plan.alloc_miss_rate,
        "channel_stall_rate": plan.channel_stall_rate,
        "blacklist_subarrays": list(plan.blacklist_subarrays),
        "smoke": smoke,
    }
    return results


def run(emit: Callable[[str, float, float], None], smoke: bool = False) -> Dict:
    """benchmarks/run.py hook: emit CSV rows + persist BENCH_faults.json."""
    results = bench(smoke=smoke)
    for name, rec in results.items():
        if name == "config":
            continue
        us = 1e6 * rec.get("seconds", 0.0)
        emit(f"faults/{name}", us, round(rec.get("speedup", 0.0), 3))
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    return results


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI mode")
    args = ap.parse_args()
    results = run(lambda n, us, d: print(f"{n},{us:.1f},{d}"), smoke=args.smoke)
    print(f"[chaos_bench] wrote {OUT_PATH}")
    f = results["alloc/faulty"]
    s = results["serve/faulty"]
    print(f"  alloc: {f['served']}/{f['n']} served, "
          f"fallback={f['fallback_fraction']:.3f}, retries={f['retries']}")
    print(f"  serve: done={s['done']} rejected={s['rejected']} "
          f"cancelled={s['cancelled']} preemptions={s['preemptions']} "
          f"p99={s['p99_steps']}")
    print(f"  deterministic: {results['determinism']['identical']}")


if __name__ == "__main__":
    main()
