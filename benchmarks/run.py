"""Benchmark harness — one module per paper table/figure.

  * alloc_fraction  — paper §1 motivation (PUD-executable fraction)
  * microbench      — paper Figure 2 (zero/copy/aand speedups vs malloc)
  * kv_pool_bench   — TPU adaptation (block-table contiguity per policy)
  * kernel_bench    — kernel reference-path timings + agreement
  * roofline_report — §Roofline table (requires launch/roofline.py output)

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        alloc_fraction,
        kernel_bench,
        kv_pool_bench,
        microbench,
        roofline_report,
    )

    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived) -> None:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    alloc_fraction.run(emit)
    microbench.run(emit)
    kv_pool_bench.run(emit)
    kernel_bench.run(emit)
    roofline_report.run(emit)


if __name__ == "__main__":
    main()
