"""Benchmark harness — one module per paper table/figure, plus the
aggregator that folds every persisted ``BENCH_*.json`` into one summary.

  * alloc_fraction  — paper §1 motivation (PUD-executable fraction,
                      now also per-channel)
  * microbench      — paper Figure 2 (zero/copy/aand speedups vs malloc)
  * kv_pool_bench   — TPU adaptation (block-table contiguity per policy)
  * kernel_bench    — kernel reference-path timings + agreement
  * roofline_report — §Roofline table (requires launch/roofline.py output)
  * translate_bench — vectorized translation/planning fast path vs the seed
                      scalar algorithms (persists BENCH_translate.json)
  * channel_bench   — multi-channel PUD scaling + controller contention
                      (persists BENCH_channels.json)
  * chaos_bench     — degraded-mode metrics under the fixed-seed fault
                      plan (persists BENCH_faults.json)
  * churn_bench     — long-horizon aging: executable-fraction decay per
                      allocator + watermark compaction recovery + journal
                      crash/replay (persists BENCH_churn.json)
  * serve_bench     — open-loop serving load scenarios through ServeEngine
                      (traffic generators + tenant mixes; persists
                      BENCH_serve.json)
  * trace_bench     — GEMV/MoE decode offload fractions per allocator +
                      channel-striped makespan + serve-trace replay verdict
                      (persists BENCH_trace.json)

Prints ``name,us_per_call,derived`` CSV rows.  ``--smoke`` shrinks the
persisted microbenchmarks for CI; ``--only translate`` runs just one
module.  After the selected modules run, every ``BENCH_*.json`` found in
the working directory is folded into ``BENCH_summary.json`` under the
shared record schema ``{bench, name, speedup, seconds, config}``
(missing fields null); ``--aggregate-only`` skips the benchmarks and only
rebuilds the summary from whatever JSON files already exist.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List

SUMMARY_PATH = "BENCH_summary.json"


def aggregate(pattern: str = "BENCH_*.json") -> List[Dict]:
    """Fold every persisted benchmark file into shared-schema records.

    Each source file maps record names to dicts with (a subset of) the
    shared fields; anything non-dict (e.g. a ``config`` block) is carried
    into the records of its file as ``config`` context.
    """
    rows: List[Dict] = []
    for path in sorted(glob.glob(pattern)):
        if os.path.basename(path) == SUMMARY_PATH:
            continue
        bench = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[aggregate] skipping {path}: {e}", file=sys.stderr)
            continue
        shared_cfg = data.get("config") if isinstance(data, dict) else None
        if not isinstance(data, dict):
            continue
        for name, rec in data.items():
            if name == "config" or not isinstance(rec, dict):
                continue
            rows.append({
                "bench": bench,
                "name": name,
                "n": rec.get("n"),
                "speedup": rec.get("speedup"),
                "seconds": rec.get("seconds"),
                "config": rec.get("config", shared_cfg),
            })
    return rows


def write_summary(rows: List[Dict]) -> None:
    with open(SUMMARY_PATH, "w") as f:
        json.dump({"records": rows}, f, indent=1, sort_keys=True)
    benches = sorted({r["bench"] for r in rows})
    print(
        f"[aggregate] {len(rows)} records from {len(benches)} benchmarks "
        f"({', '.join(benches)}) -> {SUMMARY_PATH}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced sizes (CI)")
    ap.add_argument("--only", default=None,
                    help="run a single module (e.g. 'translate')")
    ap.add_argument("--aggregate-only", action="store_true",
                    help="skip benchmarks; rebuild BENCH_summary.json")
    args = ap.parse_args()

    if not args.aggregate_only:
        from benchmarks import (
            alloc_fraction,
            channel_bench,
            chaos_bench,
            churn_bench,
            kernel_bench,
            kv_pool_bench,
            microbench,
            roofline_report,
            serve_bench,
            trace_bench,
            translate_bench,
        )

        print("name,us_per_call,derived")

        def emit(name: str, us: float, derived) -> None:
            print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()

        modules = {
            "alloc_fraction": lambda: alloc_fraction.run(emit),
            "microbench": lambda: microbench.run(emit),
            "kv_pool": lambda: kv_pool_bench.run(emit),
            "kernel": lambda: kernel_bench.run(emit),
            "roofline": lambda: roofline_report.run(emit),
            "translate": lambda: translate_bench.run(emit, smoke=args.smoke),
            "channels": lambda: channel_bench.run(emit, smoke=args.smoke),
            "chaos": lambda: chaos_bench.run(emit, smoke=args.smoke),
            "churn": lambda: churn_bench.run(emit, smoke=args.smoke),
            "serve": lambda: serve_bench.run(emit, smoke=args.smoke),
            "trace": lambda: trace_bench.run(emit, smoke=args.smoke),
        }
        selected = {
            name: fn
            for name, fn in modules.items()
            if args.only is None or args.only in name
        }
        if not selected:
            raise SystemExit(
                f"--only {args.only!r} matches no module ({', '.join(modules)})"
            )
        for fn in selected.values():
            fn()

    write_summary(aggregate())


if __name__ == "__main__":
    main()
