"""Benchmark harness — one module per paper table/figure.

  * alloc_fraction  — paper §1 motivation (PUD-executable fraction)
  * microbench      — paper Figure 2 (zero/copy/aand speedups vs malloc)
  * kv_pool_bench   — TPU adaptation (block-table contiguity per policy)
  * kernel_bench    — kernel reference-path timings + agreement
  * roofline_report — §Roofline table (requires launch/roofline.py output)
  * translate_bench — vectorized translation/planning fast path vs the seed
                      scalar algorithms (persists BENCH_translate.json)

Prints ``name,us_per_call,derived`` CSV rows.  ``--smoke`` shrinks the
translate microbenchmark for CI; ``--only translate`` runs just it.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced sizes (CI)")
    ap.add_argument("--only", default=None,
                    help="run a single module (e.g. 'translate')")
    args = ap.parse_args()

    from benchmarks import (
        alloc_fraction,
        kernel_bench,
        kv_pool_bench,
        microbench,
        roofline_report,
        translate_bench,
    )

    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived) -> None:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    modules = {
        "alloc_fraction": lambda: alloc_fraction.run(emit),
        "microbench": lambda: microbench.run(emit),
        "kv_pool": lambda: kv_pool_bench.run(emit),
        "kernel": lambda: kernel_bench.run(emit),
        "roofline": lambda: roofline_report.run(emit),
        "translate": lambda: translate_bench.run(emit, smoke=args.smoke),
    }
    selected = {
        name: fn
        for name, fn in modules.items()
        if args.only is None or args.only in name
    }
    if not selected:
        raise SystemExit(
            f"--only {args.only!r} matches no module ({', '.join(modules)})"
        )
    for fn in selected.values():
        fn()


if __name__ == "__main__":
    main()
