"""Paper §1 study: fraction of PUD ops executable per allocator x size.

Reproduces the motivation numbers: malloc/posix_memalign -> 0 %, huge pages
-> partial ("up to 60 %"), PUMA -> ~100 %.

The channel view (``alloc_channel/...`` rows) breaks the same figure of
merit down per memory channel on an 8-channel geometry: the PUD-executable
fraction of the rows owned by each channel, plus the striped allocator's
per-channel subarray occupancy and its load balance — placement imbalance
caps the channel-parallel speedup at ``max`` rows per channel even when
every row is individually executable.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import pud
from repro.core.allocators import (
    HugePageModel,
    MallocModel,
    PhysicalMemory,
    PosixMemalignModel,
)
from repro.core.dram import AddressMap, BANK_REGION_SCHEME, DramGeometry
from repro.core.puma import PumaAllocator

SIZES_BITS = [2_000, 8_000, 32_000, 128_000, 512_000, 2_000_000, 6_000_000]
OPS = {"zero": 1, "copy": 2, "aand": 3}
REPS = 10


def _fraction(amap, mk_alloc, op: str, nops: int, size: int) -> float:
    fr = []
    for rep in range(REPS):
        mem = PhysicalMemory(amap, seed=rep)
        al = mk_alloc(mem)
        ops = [al.alloc(size) for _ in range(nops)]
        fr.append(pud.plan_rows(op.replace("aand", "and"), ops, amap).pud_fraction)
    return float(np.mean(fr))


def _fraction_puma(amap, op: str, nops: int, size: int) -> float:
    fr = []
    for rep in range(REPS):
        mem = PhysicalMemory(amap, seed=rep)
        pa = PumaAllocator(mem)
        pa.pim_preallocate(64)
        ops = [pa.pim_alloc(size)]
        while len(ops) < nops:
            ops.append(pa.pim_alloc_align(size, ops[0]))
        fr.append(pud.plan_rows(op.replace("aand", "and"), ops, amap).pud_fraction)
    return float(np.mean(fr))


def _channel_view(emit: Callable[[str, float, float], None]) -> Dict:
    """Per-channel subarray occupancy + executable fraction (8 channels)."""
    amap = AddressMap(
        DramGeometry(channels=8, subarrays_per_bank=128), BANK_REGION_SCHEME
    )
    C = amap.geo.channels
    out: Dict[str, Dict] = {}
    for policy, stripe in [("striped", True), ("stacked", False)]:
        mem = PhysicalMemory(amap, seed=0, n_huge_pages=128, huge_scatter=1.0)
        al = PumaAllocator(mem, amap, stripe_channels=stripe)
        al.pim_preallocate(64)
        # a serving-like mix of operand sizes
        allocs = [al.pim_alloc(s) for s in (64 * 1024, 128 * 1024, 256 * 1024)]

        # executable rows per owning channel, summed over one op per alloc
        pud_rows = np.zeros(C, dtype=np.int64)
        region_rows = np.zeros(C, dtype=np.int64)
        for a in allocs:
            t0 = time.perf_counter()
            plan = pud.plan_rows("zero", [a], amap)
            us = (time.perf_counter() - t0) * 1e6
            pud_rows += plan.channel_rows(amap)
            pas = np.array([e.pa for e in a.extents], dtype=np.int64)
            nreg = np.array([e.nbytes for e in a.extents]) // amap.region_bytes
            region_rows += np.bincount(
                np.repeat(amap.region_channels(pas), nreg), minlength=C
            )
        frac = np.divide(
            pud_rows, region_rows, out=np.ones(C), where=region_rows > 0
        )
        rep = al.channel_report()
        used = np.asarray(rep["used_regions"], dtype=np.float64)
        occ_balance = float(used.mean() / used.max()) if used.max() else 1.0
        row_balance = (
            float(pud_rows.mean() / pud_rows.max()) if pud_rows.max() else 1.0
        )
        for c in range(C):
            emit(f"alloc_channel/{policy}/frac/ch{c}", us, round(frac[c], 3))
            emit(
                f"alloc_channel/{policy}/occupancy/ch{c}", 0.0, int(used[c])
            )
        emit(f"alloc_channel/{policy}/occupancy_balance", 0.0, occ_balance)
        emit(f"alloc_channel/{policy}/pud_row_balance", 0.0, row_balance)
        out[policy] = {
            "pud_fraction_per_channel": frac.tolist(),
            "used_regions_per_channel": used.astype(int).tolist(),
            "occupancy_balance": occ_balance,
            "pud_row_balance": row_balance,
        }
    return out


def run_churned(
    emit: Callable[[str, float, float], None], cycles: int
) -> Dict:
    """``--churn-cycles N`` mode: the §1 figure of merit measured against a
    churn-*aged* PUMA pool instead of a fresh one — fresh fraction, aged
    fraction, and the fraction after watermark compaction (the long-horizon
    counterpart of the static table; full curves live in
    ``benchmarks/churn_bench.py``)."""
    try:
        from benchmarks.churn_bench import _puma_arm
    except ImportError:       # invoked as a script from inside benchmarks/
        from churn_bench import _puma_arm

    sample_every = max(1, cycles // 20)
    aged, _, _ = _puma_arm(cycles, sample_every, compaction=False)
    compacted, _, _ = _puma_arm(cycles, sample_every, compaction=True)
    out = {
        "cycles": cycles,
        "fresh": aged["frac_start"],
        "aged": aged["frac_end"],
        "compacted": compacted["frac_end"],
        "compaction_passes": len(compacted["compactions"]),
    }
    emit(f"alloc_fraction/churned/{cycles}/fresh",
         1e6 * aged["seconds"], out["fresh"])
    emit(f"alloc_fraction/churned/{cycles}/aged", 0.0, out["aged"])
    emit(f"alloc_fraction/churned/{cycles}/compacted",
         1e6 * compacted["seconds"], out["compacted"])
    return out


def run(emit: Callable[[str, float, float], None]) -> Dict:
    amap = AddressMap()
    allocators = {
        "malloc": lambda m: MallocModel(m),
        "posix_memalign": lambda m: PosixMemalignModel(m),
        "hugepage": lambda m: HugePageModel(m, "mmap"),
    }
    table: Dict[str, Dict[int, float]] = {}
    for op, nops in OPS.items():
        for name, mk in allocators.items():
            for bits in SIZES_BITS:
                t0 = time.perf_counter()
                f = _fraction(amap, mk, op, nops, max(1, bits // 8))
                us = (time.perf_counter() - t0) * 1e6 / REPS
                emit(f"alloc_fraction/{op}/{name}/{bits}b", us, f)
                table.setdefault(f"{op}/{name}", {})[bits] = f
        for bits in SIZES_BITS:
            t0 = time.perf_counter()
            f = _fraction_puma(amap, op, nops, max(1, bits // 8))
            us = (time.perf_counter() - t0) * 1e6 / REPS
            emit(f"alloc_fraction/{op}/puma/{bits}b", us, f)
            table.setdefault(f"{op}/puma", {})[bits] = f
    table["channel_view"] = _channel_view(emit)
    return table


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--churn-cycles", type=int, default=0, metavar="N",
        help="age the PUMA pool with N alloc/free cycles before measuring "
             "(reports fresh vs aged vs compacted fractions)",
    )
    args = ap.parse_args()

    def emit(name: str, us: float, derived) -> None:
        print(f"{name},{us:.1f},{derived}")

    if args.churn_cycles:
        out = run_churned(emit, args.churn_cycles)
        print(f"[alloc_fraction] churned {out['cycles']} cycles: "
              f"fresh={out['fresh']} aged={out['aged']} "
              f"compacted={out['compacted']} "
              f"({out['compaction_passes']} passes)")
    else:
        run(emit)


if __name__ == "__main__":
    main()
