"""Paper §1 study: fraction of PUD ops executable per allocator x size.

Reproduces the motivation numbers: malloc/posix_memalign -> 0 %, huge pages
-> partial ("up to 60 %"), PUMA -> ~100 %.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import pud
from repro.core.allocators import (
    HugePageModel,
    MallocModel,
    PhysicalMemory,
    PosixMemalignModel,
)
from repro.core.dram import AddressMap
from repro.core.puma import PumaAllocator

SIZES_BITS = [2_000, 8_000, 32_000, 128_000, 512_000, 2_000_000, 6_000_000]
OPS = {"zero": 1, "copy": 2, "aand": 3}
REPS = 10


def _fraction(amap, mk_alloc, op: str, nops: int, size: int) -> float:
    fr = []
    for rep in range(REPS):
        mem = PhysicalMemory(amap, seed=rep)
        al = mk_alloc(mem)
        ops = [al.alloc(size) for _ in range(nops)]
        fr.append(pud.plan_rows(op.replace("aand", "and"), ops, amap).pud_fraction)
    return float(np.mean(fr))


def _fraction_puma(amap, op: str, nops: int, size: int) -> float:
    fr = []
    for rep in range(REPS):
        mem = PhysicalMemory(amap, seed=rep)
        pa = PumaAllocator(mem)
        pa.pim_preallocate(64)
        ops = [pa.pim_alloc(size)]
        while len(ops) < nops:
            ops.append(pa.pim_alloc_align(size, ops[0]))
        fr.append(pud.plan_rows(op.replace("aand", "and"), ops, amap).pud_fraction)
    return float(np.mean(fr))


def run(emit: Callable[[str, float, float], None]) -> Dict:
    amap = AddressMap()
    allocators = {
        "malloc": lambda m: MallocModel(m),
        "posix_memalign": lambda m: PosixMemalignModel(m),
        "hugepage": lambda m: HugePageModel(m, "mmap"),
    }
    table: Dict[str, Dict[int, float]] = {}
    for op, nops in OPS.items():
        for name, mk in allocators.items():
            for bits in SIZES_BITS:
                t0 = time.perf_counter()
                f = _fraction(amap, mk, op, nops, max(1, bits // 8))
                us = (time.perf_counter() - t0) * 1e6 / REPS
                emit(f"alloc_fraction/{op}/{name}/{bits}b", us, f)
                table.setdefault(f"{op}/{name}", {})[bits] = f
        for bits in SIZES_BITS:
            t0 = time.perf_counter()
            f = _fraction_puma(amap, op, nops, max(1, bits // 8))
            us = (time.perf_counter() - t0) * 1e6 / REPS
            emit(f"alloc_fraction/{op}/puma/{bits}b", us, f)
            table.setdefault(f"{op}/puma", {})[bits] = f
    return table
