"""Long-horizon churn benchmark (ISSUE 8): allocator aging + compaction.

Drives 100k-scale seeded alloc/free churn through every allocator model and
records how the paper's figure of merit — the PUD-executable fraction of
fresh operand pairs — decays as free capacity fragments, then how much of
it the RowClone-priced compaction engine recovers:

* ``alloc/<baseline>`` — malloc / posix_memalign / hugepage-mmap churn on
  the default 8 GB geometry: the flat reference lines (base pages never
  co-locate; huge pages co-locate opportunistically).
* ``alloc/robust`` — :class:`~repro.core.puma.RobustAllocator` churn on a
  deliberately tight PUD pool: the fallback-tier mix under pressure.
* ``alloc/puma`` vs ``alloc/puma_compact`` — the same seeded churn twice:
  aging only, and aging with watermark-triggered
  :func:`~repro.robustness.compaction.compact_allocator` passes.  The
  compaction arm journals every event, moves real bytes on a modeled
  physical memory (verified bit-exact after every pass), and reports
  ``recovery`` — the fraction of churn-lost executable fraction the
  compaction engine won back (the CI gate asserts >= 0.5).
* ``pool/serving_trace`` — a serving-engine-shaped trace (admissions,
  per-token extends, releases; request shapes from the config registry)
  on :class:`~repro.core.kv_pool.PagedKVPool`, with watermark
  ``compact()`` passes stamped and verified bit-exact through the block
  tables.
* ``journal/crash_replay`` — the compaction arm's journal truncated
  mid-history and replayed twice: digests must match each other (replay
  is deterministic) and the full log must reproduce the live allocator.

``run(emit)`` plugs into ``benchmarks/run.py``; ``main()`` (``--smoke``,
``--gate``) persists ``BENCH_churn.json`` and optionally enforces the
acceptance thresholds.
"""
from __future__ import annotations

import json
import random
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import pud
from repro.core.allocators import (
    PAGE,
    Allocation,
    HugePageModel,
    MallocModel,
    PhysicalMemory,
    PosixMemalignModel,
)
from repro.core.dram import AddressMap, DramGeometry
from repro.core.puma import PumaAllocator, RobustAllocator

OUT_PATH = "BENCH_churn.json"
CHURN_SEED = 0xC0FFEE

#: default paper geometry for the baseline models (pages never run out)
AMAP = AddressMap()
#: small geometry for the PUMA arms: 16 MB total (so the bit-exactness
#: check can shadow the whole physical memory in one ndarray) carved into
#: 1 MB subarrays of 32 regions — the PUD pool spans ~8 subarrays, enough
#: for fragmentation to spread capacity thin across them.
SMALL_AMAP = AddressMap(
    DramGeometry(channels=4, subarrays_per_bank=16, rows_per_subarray=32)
)
N_HUGE = 4            # PhysicalMemory caps the huge pool at half of memory


# ---------------------------------------------------------------------------
# probes: executable fraction of *fresh* operand pairs
# ---------------------------------------------------------------------------

def _probe_fraction(
    alloc, free, amap: AddressMap, size: int, n_pairs: int = 8
) -> float:
    """Allocate ``n_pairs`` copy-operand pairs, measure the mean
    PUD-executable fraction, free them — "can new work still co-locate".
    """
    fr: List[float] = []
    for _ in range(n_pairs):
        a = alloc(size, None)
        if a is None:
            fr.append(0.0)          # pool too fragmented to even start
            break
        b = alloc(size, a)
        if b is None:
            fr.append(0.0)
            free(a)
            break
        fr.append(pud.plan_rows("copy", [a, b], amap).pud_fraction)
        free(b)
        free(a)
    return float(np.mean(fr)) if fr else 0.0


# ---------------------------------------------------------------------------
# baseline models: churn + flat reference lines
# ---------------------------------------------------------------------------

def _release_baseline(mem: PhysicalMemory, a: Allocation) -> None:
    if a.allocator.startswith("hugepage"):
        mem.release_huge([e.pa for e in a.extents])
    else:
        pas = [
            e.pa + off
            for e in a.extents
            for off in range(0, e.nbytes, PAGE)
        ]
        mem.release_pages(pas)


def _baseline_churn(name: str, mk, cycles: int, sample_every: int) -> Dict:
    mem = PhysicalMemory(AMAP, seed=0, n_huge_pages=1024)
    al = mk(mem)
    rng = random.Random(CHURN_SEED)
    region = AMAP.region_bytes
    # >= MMAP_THRESHOLD so even the malloc model's churn is page-backed
    # (its heap path is a bump pointer and never frees)
    sizes = [max(s, 128 * 1024) for s in
             (2 * region, 3 * region, 4 * region, 6 * region)]

    def alloc(size, hint):
        return al.alloc(size)

    def free(a):
        _release_baseline(mem, a)

    live: List[Allocation] = []
    curve: List[List[float]] = []
    t0 = time.perf_counter()
    for cycle in range(cycles):
        if live and (len(live) >= 256 or rng.random() < 0.5):
            _release_baseline(mem, live.pop(rng.randrange(len(live))))
        else:
            live.append(al.alloc(rng.choice(sizes)))
        if cycle % sample_every == sample_every - 1:
            curve.append([
                cycle + 1,
                round(_probe_fraction(alloc, free, AMAP, 2 * region), 4),
            ])
    seconds = time.perf_counter() - t0
    for a in live:
        _release_baseline(mem, a)
    return {
        "n": cycles,
        "seconds": seconds,
        "curve": curve,
        "frac_mean": round(float(np.mean([c[1] for c in curve])), 4),
    }


def _robust_churn(cycles: int, sample_every: int) -> Dict:
    """RobustAllocator on a tight pool: tier mix + probe fraction."""
    mem = PhysicalMemory(SMALL_AMAP, seed=3, n_huge_pages=N_HUGE)
    pa = PumaAllocator(mem)
    pa.pim_preallocate(N_HUGE - 2)
    ra = RobustAllocator(pa, refill_huge_pages=1)
    region = SMALL_AMAP.region_bytes
    rng = random.Random(CHURN_SEED)
    live: List[Allocation] = []
    curve: List[List[float]] = []
    t0 = time.perf_counter()
    for cycle in range(cycles):
        if live and (rng.random() < 0.45 or pa.free_regions() < 8):
            ra.free(live.pop(rng.randrange(len(live))))
        else:
            live.append(ra.alloc(rng.randint(1, 4 * region)))
        if cycle % sample_every == sample_every - 1:
            curve.append([
                cycle + 1,
                round(_probe_fraction(
                    lambda s, h: ra.alloc(s, hint=h), ra.free,
                    SMALL_AMAP, 2 * region,
                ), 4),
            ])
    seconds = time.perf_counter() - t0
    for a in live:
        ra.free(a)
    st = ra.stats
    return {
        "n": cycles,
        "seconds": seconds,
        "curve": curve,
        "tiers": {"puma": st.puma, "huge": st.huge, "base": st.base},
        "fallback_fraction": round(st.fallback_fraction(), 4),
    }


# ---------------------------------------------------------------------------
# the PUMA aging arms (decay vs watermark compaction)
# ---------------------------------------------------------------------------

def _puma_arm(
    cycles: int,
    sample_every: int,
    *,
    compaction: bool,
    frag_watermark: float = 0.35,
    max_moves: int = 64,
) -> Tuple[Dict, Optional[object], Optional["PumaAllocator"]]:
    """One seeded churn run; returns (record, journal, allocator)."""
    from repro.robustness.compaction import compact_allocator
    from repro.robustness.invariants import check_allocator
    from repro.robustness.journal import Journal

    journal = Journal() if compaction else None
    mem = PhysicalMemory(SMALL_AMAP, seed=7, n_huge_pages=N_HUGE)
    pa = PumaAllocator(mem, journal=journal)
    pa.pim_preallocate(N_HUGE)
    region = pa.region_bytes
    total = pa.free_regions()
    phys = np.zeros(SMALL_AMAP.total_bytes, np.uint8) if compaction else None
    expected: Dict[int, np.ndarray] = {}

    rng = random.Random(CHURN_SEED)
    data_rng = np.random.default_rng(CHURN_SEED)

    def fill(a: Allocation) -> None:
        n = sum(e.nbytes for e in a.extents)
        data = data_rng.integers(0, 256, n, dtype=np.uint8)
        for e in a.extents:
            phys[e.pa:e.pa + e.nbytes] = data[e.va_off:e.va_off + e.nbytes]
        expected[a.va] = data

    def read_back(a: Allocation) -> np.ndarray:
        return np.concatenate([
            phys[e.pa:e.pa + e.nbytes]
            for e in sorted(a.extents, key=lambda e: e.va_off)
        ])

    def alloc(size: int, hint: Optional[Allocation]) -> Optional[Allocation]:
        a = (pa.pim_alloc_align(size, hint) if hint is not None
             else pa.pim_alloc(size))
        if a is not None and compaction:
            fill(a)
        return a

    def free(a: Allocation) -> None:
        if compaction:
            expected.pop(a.va, None)
        pa.pim_free(a)

    probe_size = 8 * region      # a quarter-subarray operand: co-locating
                                 # the pair needs one subarray with 16 free
                                 # regions — trivial when free capacity is
                                 # concentrated, impossible once churn has
                                 # spread it thin
    live: List[Allocation] = []
    curve: List[Dict] = []
    compactions: List[Dict] = []
    bit_exact = True

    def sample(cycle: int) -> float:
        frac = _probe_fraction(alloc, free, SMALL_AMAP, probe_size)
        curve.append({
            "cycle": cycle,
            "frac": round(frac, 4),
            "frag": round(pa.fragmentation(), 4),
            "free_regions": pa.free_regions(),
        })
        return frac

    t0 = time.perf_counter()
    sample(0)                    # fresh-pool reference point
    for cycle in range(cycles):
        # aging mix: operand pairs (alloc + aligned partner) and odd
        # singles, freed independently, pressure held near 90 % utilization
        roll = rng.random()
        if live and (pa.free_regions() < total // 10 or roll < 0.45):
            free(live.pop(rng.randrange(len(live))))
        elif roll < 0.85:
            size = rng.randint(region // 2, 4 * region)
            a = alloc(size, None)
            if a is not None:
                live.append(a)
                b = alloc(size, a)
                if b is not None:
                    live.append(b)
        else:
            a = alloc(rng.randint(region // 2, 2 * region), None)
            if a is not None:
                live.append(a)
        if cycle % sample_every != sample_every - 1:
            continue
        sample(cycle + 1)
        if compaction and pa.fragmentation() > frag_watermark:
            rep = compact_allocator(pa, max_moves=max_moves, phys=phys)
            check_allocator(pa).assert_ok()
            for a in live[:32]:
                if not np.array_equal(read_back(a), expected[a.va]):
                    bit_exact = False
            compactions.append({
                "cycle": cycle + 1,
                "moves": rep.executed,
                "frag_before": round(rep.frag_before, 4),
                "frag_after": round(rep.frag_after, 4),
                "total_ns": round(rep.total_ns, 1),
            })
            sample(cycle + 1)    # post-compaction point on the curve
    seconds = time.perf_counter() - t0

    rec = {
        "n": cycles,
        "seconds": seconds,
        "curve": curve,
        "frac_start": curve[0]["frac"] if curve else None,
        "frac_end": curve[-1]["frac"] if curve else None,
    }
    if compaction:
        rec["compactions"] = compactions
        rec["bit_exact"] = bit_exact
        rec["journal_events"] = len(journal.events)
    return rec, journal, pa


def _crash_replay(journal, pa_live) -> Dict:
    """Truncate the journal mid-history, replay twice, compare digests."""
    from repro.robustness.invariants import check_allocator
    from repro.robustness.journal import allocator_digest, replay_allocator

    def fresh_mem():
        return PhysicalMemory(SMALL_AMAP, seed=7, n_huge_pages=N_HUGE)

    full = replay_allocator(journal, fresh_mem())
    live_matches = allocator_digest(full) == allocator_digest(pa_live)
    crash = journal.crash_copy(max(1, len(journal.events) // 2))
    r1 = replay_allocator(crash, fresh_mem())
    r2 = replay_allocator(crash, fresh_mem())
    check_allocator(r1).assert_ok()
    deterministic = allocator_digest(r1) == allocator_digest(r2)
    return {
        "n": len(journal.events),
        "kept_events": len(crash.events),
        "full_replay_matches_live": live_matches,
        "crash_replay_deterministic": deterministic,
        "identical": live_matches and deterministic,
    }


# ---------------------------------------------------------------------------
# serving-engine-shaped tile-pool trace
# ---------------------------------------------------------------------------

def _pool_trace(cycles: int, sample_every: int) -> Dict:
    """Admission/extend/release trace shaped like the serving engine
    (request geometry from the config registry), with watermark
    ``PagedKVPool.compact()`` passes verified bit-exact through the
    block tables."""
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.core.kv_pool import KVPoolConfig, PagedKVPool
    from repro.robustness.invariants import check_kv_pool
    from repro.robustness.journal import (
        Journal,
        kv_pool_digest,
        replay_kv_pool,
    )

    mcfg = get_config("stablelm_1_6b").smoke()
    cfg = KVPoolConfig(
        num_blocks=256, block_size=4, kv_heads=mcfg.n_kv_heads,
        head_dim=mcfg.hd, n_layers=mcfg.n_layers, max_seqs=32,
        max_blocks_per_seq=64, blocks_per_arena=32, policy="puma",
        dtype="float32",
    )
    journal = Journal()
    kv = PagedKVPool(cfg, journal=journal)
    rng = random.Random(CHURN_SEED)
    # slot -> tokens still to decode before release
    remaining: Dict[int, int] = {}

    def contig() -> float:
        fr = [h.contiguous_run_fraction() for h, _ in kv._seqs.values()]
        return float(np.mean(fr)) if fr else 1.0

    curve: List[Dict] = []
    compactions: List[Dict] = []
    bit_exact = True
    next_compact_ok = 0
    t0 = time.perf_counter()
    for cycle in range(cycles):
        if (not remaining) or (rng.random() < 0.10 and kv._free_slots):
            prompt = rng.randint(4, 10 * cfg.block_size)
            slot = kv.admit(prompt)
            if slot is not None:
                remaining[slot] = rng.randint(1, 16 * cfg.block_size)
        elif remaining:
            slot = rng.choice(sorted(remaining))
            if kv.append_token(slot):
                remaining[slot] -= 1
            else:
                remaining[slot] = 0            # pool full: finish it now
            if remaining[slot] <= 0:
                del remaining[slot]
                kv.release(slot)
        if cycle % sample_every != sample_every - 1:
            continue
        c = contig()
        frag = kv.pool.fragmentation()
        curve.append({
            "cycle": cycle + 1,
            "contig": round(c, 4),
            "frag": round(frag, 4),
        })
        if cycle >= next_compact_ok and (c < 0.92 or frag > 0.5):
            # stamp each live block so the move can be audited end-to-end
            tags: Dict[int, np.ndarray] = {}
            for slot, (h, _) in kv._seqs.items():
                tg = np.asarray(
                    [slot * 1024 + i for i in range(len(h.tiles))], np.float32
                )
                tags[slot] = tg
                kv.k = kv.k.at[0, jnp.asarray(h.tiles), 0, 0, 0].set(
                    jnp.asarray(tg)
                )
            rep = kv.compact(max_moves=96)
            next_compact_ok = cycle + max(1, cycles // 10)
            if rep is None:
                continue
            check_kv_pool(kv).assert_ok()
            for slot, tg in tags.items():
                h, _ = kv._seqs[slot]
                got = np.asarray(kv.k[0, jnp.asarray(h.tiles), 0, 0, 0])
                if not np.array_equal(got, tg):
                    bit_exact = False
            compactions.append({
                "cycle": cycle + 1,
                "moves": rep.executed,
                "rowclone_rows": rep.rowclone_rows,
                "contig_before": round(c, 4),
                "contig_after": round(contig(), 4),
                "frag_before": round(rep.frag_before, 4),
                "frag_after": round(rep.frag_after, 4),
                "total_ns": round(rep.total_ns, 1),
            })
    seconds = time.perf_counter() - t0
    kv2 = replay_kv_pool(journal, cfg)
    replay_ok = kv_pool_digest(kv) == kv_pool_digest(kv2)
    return {
        "n": cycles,
        "seconds": seconds,
        "curve": curve,
        "compactions": compactions,
        "bit_exact": bit_exact,
        "replay_matches_live": replay_ok,
        "journal_events": len(journal.events),
    }


# ---------------------------------------------------------------------------

def bench(smoke: bool = False) -> Dict:
    cycles = 10_000 if smoke else 100_000
    base_cycles = 3_000 if smoke else 20_000
    pool_cycles = 8_000 if smoke else 100_000
    samples = 20

    results: Dict[str, Dict] = {}
    for name, mk in [
        ("malloc", MallocModel),
        ("posix_memalign", PosixMemalignModel),
        ("hugepage", lambda m: HugePageModel(m, "mmap")),
    ]:
        results[f"alloc/{name}"] = _baseline_churn(
            name, mk, base_cycles, base_cycles // samples
        )
    results["alloc/robust"] = _robust_churn(
        base_cycles, base_cycles // samples
    )

    aged, _, _ = _puma_arm(cycles, cycles // samples, compaction=False)
    results["alloc/puma"] = aged
    compacted, journal, pa_live = _puma_arm(
        cycles, cycles // samples, compaction=True
    )
    # recovery: the fraction of churn-lost executable fraction won back
    start = aged["frac_start"]
    lost = max(1e-9, start - aged["frac_end"])
    compacted["recovery"] = round(
        (compacted["frac_end"] - aged["frac_end"]) / lost, 4
    )
    compacted["speedup"] = compacted["recovery"]
    results["alloc/puma_compact"] = compacted

    results["journal/crash_replay"] = _crash_replay(journal, pa_live)
    results["pool/serving_trace"] = _pool_trace(
        pool_cycles, pool_cycles // samples
    )
    results["config"] = {
        "seed": CHURN_SEED,
        "cycles": cycles,
        "baseline_cycles": base_cycles,
        "pool_cycles": pool_cycles,
        "geometry": "4ch x 4sa/bank x 256 rows (32 MB)",
        "smoke": smoke,
    }
    return results


def gate(results: Dict) -> None:
    """The CI churn gate (ISSUE 8 acceptance): decay happens, compaction
    recovers >= 50 % of it bit-exactly, and replay is deterministic."""
    aged = results["alloc/puma"]
    comp = results["alloc/puma_compact"]
    assert aged["frac_end"] < aged["frac_start"] - 0.05, (
        f"expected executable-fraction decay under churn, got "
        f"{aged['frac_start']} -> {aged['frac_end']}"
    )
    assert comp["recovery"] >= 0.5, (
        f"compaction recovered {comp['recovery']:.2%} of the lost "
        f"executable fraction (< 50%)"
    )
    assert comp["compactions"], "the fragmentation watermark never tripped"
    assert comp["bit_exact"], "compaction corrupted migrated bytes"
    jr = results["journal/crash_replay"]
    assert jr["identical"], f"journal replay mismatch: {jr}"
    pt = results["pool/serving_trace"]
    assert pt["bit_exact"], "pool compaction corrupted block data"
    assert pt["replay_matches_live"], "pool journal replay diverged"
    print("[churn gate] decay={:.3f}->{:.3f} recovery={:.2%} "
          "passes={} pool_passes={} : OK".format(
              aged["frac_start"], aged["frac_end"], comp["recovery"],
              len(comp["compactions"]), len(pt["compactions"])))


def run(emit: Callable[[str, float, float], None], smoke: bool = False) -> Dict:
    """benchmarks/run.py hook: emit CSV rows + persist BENCH_churn.json."""
    results = bench(smoke=smoke)
    for name, rec in results.items():
        if name == "config":
            continue
        us = 1e6 * rec.get("seconds", 0.0)
        derived = rec.get("recovery",
                          rec.get("frac_end", rec.get("identical", 0.0)))
        emit(f"churn/{name}", us, derived)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    return results


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI mode")
    ap.add_argument("--gate", action="store_true",
                    help="assert the ISSUE 8 acceptance thresholds")
    args = ap.parse_args()
    results = run(lambda n, us, d: print(f"{n},{us:.1f},{d}"),
                  smoke=args.smoke)
    print(f"[churn_bench] wrote {OUT_PATH}")
    if args.gate:
        gate(results)


if __name__ == "__main__":
    main()
