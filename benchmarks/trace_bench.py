"""Trace & offload benchmark (ISSUE 10 tentpole): ``BENCH_trace.json``.

Three record families, all pure functions of pinned seeds:

* ``offload/{arch}/{allocator}`` — the GEMV/MoE decode offload model
  (:mod:`repro.trace.gemv`) for each arch in
  :data:`repro.configs.registry.TRACE_ARCHS` under all four allocator
  placements: PUD-offloaded row fraction, priced decode time, and the
  speedup of the adaptive PUD driver over CPU-only decode.  The §1 story
  at decode granularity: malloc/posix 0 %, hugepage partial, PUMA ~100 %
  and strictly highest.
* ``channel/{arch}`` — PUMA channel-striped placement on a 4-channel
  BANK_REGION map dispatched through a live DRAM controller: makespan,
  per-channel balance, and parallel speedup over a serial row burst.
* ``serve/steady_trace`` — the ``steady`` serving scenario recorded into a
  :mod:`repro.trace` op trace and re-priced bit-exactly by the replay
  executor (no engine in the loop); the record carries the end totals and
  the replay verdict.

``--gate`` reruns everything and asserts the canonical JSON is
byte-identical, then checks the offload ordering/speedup invariants and
the replay verdict (scripts/ci.sh re-asserts a subset from the JSON).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, Tuple

OUT_PATH = "BENCH_trace.json"


def bench(smoke: bool = False) -> Tuple[Dict, Dict[str, float]]:
    from repro.configs.registry import TRACE_ARCHS
    from repro.trace.gemv import ALLOCATORS, channel_study, offload_report
    from repro.trace.record import SCHEMA_VERSION
    from repro.trace.replay import parse_trace, replay_trace
    from repro.trace.serve_trace import record_scenario

    n_tokens = 2 if smoke else 4
    results: Dict[str, Dict] = {}
    walls: Dict[str, float] = {}
    for arch in TRACE_ARCHS:
        for al in ALLOCATORS:
            t0 = time.perf_counter()
            results[f"offload/{arch}/{al}"] = offload_report(
                arch, al, n_tokens=n_tokens
            )
            walls[f"offload/{arch}/{al}"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        results[f"channel/{arch}"] = channel_study(arch, n_tokens=n_tokens)
        walls[f"channel/{arch}"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    trace, rec = record_scenario("steady", smoke=smoke)
    text = trace.to_jsonl()
    res = replay_trace(parse_trace(text))
    end = trace.events[-1]
    results["serve/steady_trace"] = {
        "scenario": "steady",
        "smoke": smoke,
        "schema": SCHEMA_VERSION,
        "events": len(trace.events),
        "done": rec["done"],
        "submitted": rec["submitted"],
        "clock": end["clock"],
        "tokens_decoded": end["tokens_decoded"],
        "tokens_prefilled": end["tokens_prefilled"],
        "sim_ns": end["sim_ns"],
        "mem_ns": end["mem_ns"],
        "cpu_ns": end["cpu_ns"],
        "maintenance_ns": end["maintenance_ns"],
        "replay_ok": bool(res.ok),
        "replay_mismatches": len(res.mismatches),
    }
    walls["serve/steady_trace"] = time.perf_counter() - t0

    results["config"] = {
        "archs": list(TRACE_ARCHS),
        "allocators": list(ALLOCATORS),
        "n_tokens": n_tokens,
        "schema": SCHEMA_VERSION,
        "smoke": smoke,
    }
    return results, walls


def _canon(results: Dict) -> str:
    return json.dumps(results, indent=1, sort_keys=True)


def check(results: Dict) -> None:
    """Gate assertions (a subset re-checked from JSON by scripts/ci.sh)."""
    from repro.configs.registry import TRACE_ARCHS

    for arch in TRACE_ARCHS:
        frac = {
            al: results[f"offload/{arch}/{al}"]["offload_fraction"]
            for al in ("malloc", "posix_memalign", "hugepage", "puma")
        }
        sp = {
            al: results[f"offload/{arch}/{al}"]["speedup_vs_cpu"]
            for al in frac
        }
        # the paper's allocator story, at decode-step granularity
        assert frac["malloc"] == 0.0, (arch, frac)
        assert frac["posix_memalign"] == 0.0, (arch, frac)
        assert 0.0 < frac["hugepage"] < 0.95, (arch, frac)
        assert frac["puma"] >= 0.99, (arch, frac)
        for al in ("malloc", "posix_memalign", "hugepage"):
            assert frac["puma"] > frac[al], (arch, al, frac)
        # adaptive driver: never slower than CPU; PUMA clearly faster
        assert sp["malloc"] == 1.0 and sp["posix_memalign"] == 1.0, (arch, sp)
        assert sp["hugepage"] >= 1.0, (arch, sp)
        assert sp["puma"] >= 1.5, (arch, sp)
        ch = results[f"channel/{arch}"]
        assert ch["offload_fraction"] >= 0.99, (arch, ch)
        assert ch["parallel_speedup"] >= 2.0, (arch, ch)
        assert 0.0 < ch["balance"] <= 1.0, (arch, ch)
    sv = results["serve/steady_trace"]
    assert sv["replay_ok"] and sv["replay_mismatches"] == 0, sv
    assert sv["events"] > 0 and sv["sim_ns"] > 0, sv


def run(emit: Callable[[str, float, float], None], smoke: bool = False,
        gate: bool = False) -> Dict:
    """benchmarks/run.py hook: emit CSV rows + persist BENCH_trace.json."""
    results, walls = bench(smoke=smoke)
    if gate:
        rerun, _ = bench(smoke=smoke)
        results["determinism"] = {
            "identical": _canon(results) == _canon(rerun),
            "reruns": 2,
        }
        check(results)
        assert results["determinism"]["identical"], \
            "fixed-seed rerun diverged from the first pass"
    for name, wall in walls.items():
        rec = results[name]
        metric = rec.get("offload_fraction", rec.get("sim_ns", 0.0))
        emit(f"trace/{name}", 1e6 * wall, metric)
    with open(OUT_PATH, "w") as f:
        f.write(_canon(results))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI mode")
    ap.add_argument("--gate", action="store_true",
                    help="rerun and assert byte-identical + invariants")
    args = ap.parse_args()
    results = run(lambda n, us, d: print(f"{n},{us:.1f},{d}"),
                  smoke=args.smoke, gate=args.gate)
    print(f"[trace_bench] wrote {OUT_PATH}")
    for key, rec in sorted(results.items()):
        if key.startswith("offload/"):
            print(f"  {key:<45} frac={rec['offload_fraction']:<9} "
                  f"speedup={rec['speedup_vs_cpu']}")
        elif key.startswith("channel/"):
            print(f"  {key:<45} parallel={rec['parallel_speedup']} "
                  f"balance={rec['balance']}")
    sv = results["serve/steady_trace"]
    print(f"  serve/steady_trace: events={sv['events']} "
          f"replay_ok={sv['replay_ok']} sim_ns={sv['sim_ns']}")
    if "determinism" in results:
        print(f"  deterministic: {results['determinism']['identical']}")


if __name__ == "__main__":
    main()
