"""Channel-scaling benchmark (ISSUE 6 tentpole).

Sweeps channel counts x operand sizes over the N-channel, bank-parallel
DRAM model and persists ``BENCH_channels.json``:

* ``pud/<size>/ch<C>`` — simulated PUD throughput (rows/s of DRAM time) of
  a subarray-aligned 3-operand ``and`` over channel-striped PUMA
  allocations.  ``scaling/<size>`` records throughput(C) / throughput(1);
  the CI smoke gate requires >= 4x at 8 channels.
* ``plan/<size>/ch<C>`` — wall time of the vectorized channel partition
  (``RowPlan.channel_rows``: one ``bincount``) vs a scalar per-row Python
  reference, i.e. the planner cost of going multi-channel.
* ``contention/ch<C>`` — controller-level dispatch: the makespan of a burst
  of ops under striped placement vs single-channel placement on the same
  :class:`~repro.core.controller.DramController`, showing contention when
  every op lands on one queue.

Geometry: total capacity is held at 8 GB while ``channels`` sweeps
{1, 2, 4, 8, 16} (``subarrays_per_bank`` shrinks to compensate), under
``BANK_REGION_SCHEME`` where each rank-row region is owned by exactly one
channel.  The huge-page pool is fully scattered so every channel
contributes regions.

Every record carries the shared benchmark schema consumed by
``benchmarks/run.py``'s aggregator: ``n``, ``seconds`` (wall), ``speedup``
(when a baseline exists), and ``config``.

``run(emit)`` plugs into ``benchmarks/run.py``; ``main()`` (``--smoke`` or
full) persists the JSON.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import pud
from repro.core.allocators import PhysicalMemory
from repro.core.controller import ControllerConfig, DramController
from repro.core.dram import AddressMap, BANK_REGION_SCHEME, DramGeometry
from repro.core.puma import PumaAllocator

OUT_PATH = "BENCH_channels.json"

CHANNEL_COUNTS = [1, 2, 4, 8, 16]
# 3 same-subarray operands must fit one 1024-row subarray per channel
# stripe at channels=1, so per-operand size tops out at 256 KB (256 rows).
SIZES = {"64k": 64 * 1024, "128k": 128 * 1024, "256k": 256 * 1024}
SMOKE_CHANNELS = [1, 2, 8]
SMOKE_SIZES = {"256k": 256 * 1024}
BASE_SUBARRAYS = 1024   # at channels=1 -> the paper's 8 GB geometry


def make_amap(channels: int) -> AddressMap:
    """8 GB total regardless of channel count (capacity-neutral sweep)."""
    geo = DramGeometry(
        channels=channels, subarrays_per_bank=BASE_SUBARRAYS // channels
    )
    return AddressMap(geo, BANK_REGION_SCHEME)


def striped_operands(
    amap: AddressMap, size: int, n_ops: int, seed: int = 0
) -> List:
    """Subarray-aligned, channel-striped PUMA operands (fraction 1.0)."""
    mem = PhysicalMemory(amap, seed=seed, n_huge_pages=256, huge_scatter=1.0)
    alloc = PumaAllocator(mem, stripe_channels=True)
    alloc.pim_preallocate(128)
    ops = [alloc.pim_alloc(size)]
    while len(ops) < n_ops:
        ops.append(alloc.pim_alloc_align(size, ops[0]))
    return ops


def scalar_channel_partition(plan: pud.RowPlan, amap: AddressMap) -> int:
    """Scalar reference of the vectorized planner: per-row Python loop
    computing the owning channel and the serial/parallel row maximum."""
    C = amap.geo.channels
    counts = [0] * C
    for r in range(plan.n_rows):
        if plan.in_pud[r]:
            counts[int(plan.subarrays[r]) % C] += 1
    return max(counts) if counts else 0


def _best_of(fn: Callable[[], object], repeats: int) -> float:
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench(smoke: bool = False) -> Dict:
    channels = SMOKE_CHANNELS if smoke else CHANNEL_COUNTS
    sizes = SMOKE_SIZES if smoke else SIZES
    repeats = 3 if smoke else 10
    results: Dict[str, Dict] = {}
    results["config"] = {
        "channels": channels,
        "sizes": {k: v for k, v in sizes.items()},
        "scheme": "bank_region",
        "total_bytes": 8 * 2**30,
        "op": "and",
        "smoke": smoke,
    }

    for sname, size in sizes.items():
        tput: Dict[int, float] = {}
        for C in channels:
            amap = make_amap(C)
            cfg = {"channels": C, "size": size}
            operands = striped_operands(amap, size, 3)

            # -- simulated PUD throughput (the model's figure of merit;
            # adaptive off: we want pure DRAM time, not the CPU fallback) --
            res = pud.simulate_op("and", operands, amap, adaptive=False)
            n_rows = res.rows_per_channel and sum(res.rows_per_channel) or 0
            assert res.pud_fraction == 1.0, (sname, C, res.pud_fraction)
            tput[C] = n_rows / res.t_ns  # rows per simulated ns
            results[f"pud/{sname}/ch{C}"] = {
                "n": n_rows,
                "t_ns": res.t_ns,
                "rows_per_us": 1e3 * tput[C],
                "channel_balance": res.channel_balance,
                "rows_per_channel": res.rows_per_channel,
                "config": cfg,
            }

            # -- planner: vectorized bincount partition vs scalar loop ----
            plan = pud.plan_rows("and", operands, amap)
            t_vec = _best_of(
                lambda: int(plan.channel_rows(amap).max()), repeats * 10
            )
            t_scalar = _best_of(
                lambda: scalar_channel_partition(plan, amap), repeats
            )
            results[f"plan/{sname}/ch{C}"] = {
                "n": plan.n_rows,
                "seconds": t_vec,
                "scalar_seconds": t_scalar,
                "speedup": t_scalar / t_vec if t_vec > 0 else float("inf"),
                "config": cfg,
            }

        # -- throughput scaling vs 1 channel (or the smallest swept) -------
        base = min(tput)
        for C in channels:
            results[f"scaling/{sname}/ch{C}"] = {
                "n": C,
                "speedup": tput[C] / tput[base],
                "config": {"baseline_channels": base, "size": size},
            }

    # -- controller-level contention: striped vs single-channel placement --
    for C in channels:
        if C == 1:
            continue
        amap = make_amap(C)
        size = 512 * 1024
        striped = striped_operands(amap, size, 1)
        # same rows forced onto one channel: an unstriped worst-fit alloc
        mem = PhysicalMemory(amap, seed=1, n_huge_pages=256, huge_scatter=1.0)
        alloc = PumaAllocator(mem, stripe_channels=False)
        alloc.pim_preallocate(128)
        single = [alloc.pim_alloc(size)]
        n_burst = 4

        def makespan(ops_list) -> float:
            ctrl = DramController(amap, ControllerConfig())
            for _ in range(n_burst):
                pud.simulate_op("zero", ops_list, amap, controller=ctrl)
            return ctrl.now_ns

        span_single = makespan(single)
        span_striped = makespan(striped)
        results[f"contention/ch{C}"] = {
            "n": n_burst,
            "makespan_striped_ns": span_striped,
            "makespan_single_channel_ns": span_single,
            "speedup": span_single / span_striped,
            "config": {"channels": C, "size": size, "burst": n_burst},
        }
    return results


def run(emit: Callable[[str, float, float], None], smoke: bool = False) -> Dict:
    """benchmarks/run.py hook: emit CSV rows + persist BENCH_channels.json."""
    results = bench(smoke=smoke)
    for name, rec in results.items():
        if name == "config":
            continue
        us = 1e6 * rec.get("seconds", 0.0)
        emit(f"channels/{name}", us, round(rec.get("speedup", 0.0), 2))
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    return results


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI mode")
    args = ap.parse_args()
    results = run(lambda n, us, d: print(f"{n},{us:.1f},{d}"), smoke=args.smoke)
    print(f"[channel_bench] wrote {OUT_PATH}")
    for name, rec in sorted(results.items()):
        if name.startswith("scaling/") or name.startswith("contention/"):
            print(f"  {name}: {rec['speedup']:.2f}x")


if __name__ == "__main__":
    main()
