"""TPU adaptation study: KV-pool placement policy vs block-table contiguity
(the '% executable in PUD' analogue) under serving churn, plus the modeled
DMA-descriptor reduction."""
from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np

from repro.core.kv_pool import KVPoolConfig, PagedKVPool


def _churn(policy: str, steps: int = 400, seed: int = 0) -> Dict[str, float]:
    cfg = KVPoolConfig(
        num_blocks=1024, blocks_per_arena=64, max_seqs=64, policy=policy
    )
    p = PagedKVPool(cfg)
    rng = np.random.default_rng(seed)
    live = []
    for _ in range(steps):
        if live and rng.random() < 0.45:
            p.release(live.pop(rng.integers(len(live))))
        s = p.admit(int(rng.integers(16, 192)))
        if s is not None:
            live.append(s)
        for s in live:
            p.append_token(s)
    return p.contiguity_report()


def run(emit: Callable[[str, float, float], None]) -> Dict:
    out = {}
    for policy in ["puma", "first_fit", "random"]:
        t0 = time.perf_counter()
        reps = [_churn(policy, seed=s) for s in range(3)]
        us = (time.perf_counter() - t0) * 1e6 / 3
        frac = float(np.mean([r["mean_contiguous_fraction"] for r in reps]))
        desc = float(np.mean([r["descriptors_per_tile"] for r in reps]))
        emit(f"kv_pool/contiguity/{policy}", us, round(frac, 4))
        emit(f"kv_pool/descriptors_per_tile/{policy}", us, round(desc, 4))
        out[policy] = {"contiguity": frac, "descriptors_per_tile": desc}
    return out
