"""Production-scale serving load benchmark (ISSUE 9 tentpole).

Drives the fixed-seed scenario registry (:mod:`repro.serve.loadgen`)
through :class:`~repro.serve.engine.ServeEngine` — open-loop arrivals, so
queue delay is measured rather than hidden — and persists one record per
scenario into ``BENCH_serve.json``:

* ``scenario/steady``       — fixed-rate baseline (1 request / 2 ticks)
* ``scenario/bursty``       — 8-request thundering herds, ~24-tick gaps
* ``scenario/long_context`` — prompt-heavy Poisson traffic near the
                              per-sequence block ceiling
* ``scenario/multi_tenant`` — registry-derived tenant mix (stablelm /
                              chatglm3 / granite_34b) on a 2-channel
                              striped pool
* ``scenario/cancel_heavy`` — 45% client cancellations + engine deadlines

Each record carries tokens/s (against the deterministic
:class:`~repro.serve.loadgen.SimCost` time model), p50/p99 queue and
completion latency in engine ticks, pool occupancy (mean/peak), live
block-table contiguity (the paper's PUD-executable-fraction analogue,
time-averaged over loaded steps), per-channel balance, and the
degraded-mode ledger (rejected / cancelled / preemptions / compactions).

Everything in the JSON is a pure function of the scenario seeds, so a
rerun is byte-identical — ``--gate`` runs the whole set twice and asserts
exactly that (plus ledger conservation and metric sanity); wall-clock
timings go to stdout only.  ``run(emit)`` plugs into ``benchmarks/run.py``
(``--smoke`` shrinks request counts; full mode streams ~1800 requests).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, Tuple

OUT_PATH = "BENCH_serve.json"

_MODEL_CACHE: Tuple = ()


def _model():
    """Build the smoke serving model once per process (scenarios share it)."""
    global _MODEL_CACHE
    if not _MODEL_CACHE:
        import jax

        from repro.configs.registry import get_config
        from repro.models.transformer import LM

        cfg = get_config("stablelm_1_6b").smoke()
        model = LM(cfg, attn_impl="naive", remat=None)
        params = model.init(jax.random.key(0))
        _MODEL_CACHE = (model, params)
    return _MODEL_CACHE


def make_engine(scenario):
    """Engine for one scenario: shared smoke model + the scenario's pool
    overrides, with watermark maintenance on so compaction competes with
    live traffic (the whole point of load-testing it)."""
    from repro.core.kv_pool import KVPoolConfig
    from repro.serve.engine import MaintenanceConfig, ServeEngine

    model, params = _model()
    cfg = model.cfg
    base = dict(
        num_blocks=32, block_size=8, kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        n_layers=cfg.n_layers, max_seqs=4, max_blocks_per_seq=16,
        blocks_per_arena=16, policy="puma", dtype="float32",
    )
    base.update(scenario.pool_overrides())
    return ServeEngine(
        model, params, KVPoolConfig(**base),
        use_kernel=False, maintenance=MaintenanceConfig(),
    )


def run_scenario(name: str, smoke: bool) -> Tuple[Dict, float]:
    """One scenario end to end; returns (record, wall_seconds) — wall time
    is never persisted (the JSON must be byte-reproducible)."""
    from repro.robustness import check_engine
    from repro.serve.loadgen import build_scenario, play

    sc = build_scenario(name, smoke=smoke)
    eng = make_engine(sc)
    specs = sc.generate()
    t0 = time.perf_counter()
    rec = play(eng, specs, max_steps=sc.max_steps)
    wall = time.perf_counter() - t0
    check_engine(eng).assert_ok()
    rec["scenario"] = {
        "seed": sc.seed,
        "arrival": sc.arrival.kind,
        "tenants": [t.name for t in sc.tenants],
        "pool": sc.pool_overrides(),
        "description": sc.description,
    }
    return rec, wall


def bench(smoke: bool = False) -> Tuple[Dict, Dict[str, float]]:
    from repro.serve.loadgen import SCENARIO_NAMES

    results: Dict[str, Dict] = {}
    walls: Dict[str, float] = {}
    for name in SCENARIO_NAMES:
        rec, wall = run_scenario(name, smoke)
        results[f"scenario/{name}"] = rec
        walls[name] = wall
    results["config"] = {
        "model": "stablelm_1_6b.smoke",
        "scenarios": list(SCENARIO_NAMES),
        "smoke": smoke,
        "time_model": "SimCost (deterministic; wall clock not persisted)",
    }
    return results, walls


def _canon(results: Dict) -> str:
    return json.dumps(results, indent=1, sort_keys=True)


def check(results: Dict) -> None:
    """The gate's per-scenario assertions (also run by scripts/ci.sh)."""
    from repro.serve.loadgen import SCENARIO_NAMES

    for name in SCENARIO_NAMES:
        rec = results[f"scenario/{name}"]
        assert rec["conservation_ok"], (name, "ledger leaked requests")
        assert rec["done"] > 0, (name, "nothing completed")
        assert rec["tokens_per_s"] > 0, (name, "no throughput")
        assert 0.0 <= rec["occupancy_mean"] <= rec["occupancy_peak"] <= 1.0, name
        assert 0.0 < rec["contiguity"] <= 1.0, (name, rec["contiguity"])
        if rec["p50_complete_steps"] is not None:
            assert rec["p50_complete_steps"] <= rec["p99_complete_steps"], name
        if rec["p50_queue_steps"] is not None:
            assert rec["p50_queue_steps"] <= rec["p99_queue_steps"], name
    # scenario-shape signatures: bursts queue deeper than the steady drip,
    # the cancellation mix actually cancels, the tenant mix actually mixes.
    assert (results["scenario/bursty"]["queue_depth_peak"]
            > results["scenario/steady"]["queue_depth_peak"])
    assert results["scenario/bursty"]["preemptions"] > 0, \
        "bursty pool never overcommitted — preemption path unexercised"
    assert results["scenario/cancel_heavy"]["cancelled"] > 0
    mt = results["scenario/multi_tenant"]
    assert mt["channels"] == 2
    assert sum(1 for v in mt["done_by_tenant"].values() if v > 0) >= 2


def run(emit: Callable[[str, float, float], None], smoke: bool = False,
        gate: bool = False) -> Dict:
    """benchmarks/run.py hook: emit CSV rows + persist BENCH_serve.json."""
    results, walls = bench(smoke=smoke)
    if gate:
        rerun, _ = bench(smoke=smoke)
        results["determinism"] = {
            "identical": _canon(results) == _canon(rerun),
            "reruns": 2,
        }
        check(results)
        assert results["determinism"]["identical"], \
            "fixed-seed rerun diverged from the first pass"
    for name, wall in walls.items():
        rec = results[f"scenario/{name}"]
        emit(f"serve/{name}", 1e6 * wall, rec["tokens_per_s"])
    with open(OUT_PATH, "w") as f:
        f.write(_canon(results))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI mode")
    ap.add_argument("--gate", action="store_true",
                    help="rerun the full set and assert byte-identical + sane")
    args = ap.parse_args()
    results = run(lambda n, us, d: print(f"{n},{us:.1f},{d}"),
                  smoke=args.smoke, gate=args.gate)
    print(f"[serve_bench] wrote {OUT_PATH}")
    for key, rec in results.items():
        if not key.startswith("scenario/"):
            continue
        print(
            f"  {key.split('/', 1)[1]:<13} done={rec['done']:>4}/{rec['submitted']:<4} "
            f"tok/s={rec['tokens_per_s']:>10.1f} "
            f"p50/p99={rec['p50_complete_steps']}/{rec['p99_complete_steps']} "
            f"occ={rec['occupancy_mean']:.2f} contig={rec['contiguity']:.3f} "
            f"cancel={rec['cancelled']} preempt={rec['preemptions']}"
        )
    if "determinism" in results:
        print(f"  deterministic: {results['determinism']['identical']}")


if __name__ == "__main__":
    main()
