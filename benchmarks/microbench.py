"""Paper Figure 2: *-zero / *-copy / *-aand speedup vs the malloc baseline,
allocation sizes 2 Kb .. 6 Mb, normalized exactly as the paper does."""
from __future__ import annotations

import time
from typing import Callable, Dict

from repro.core import pud
from repro.core.allocators import MallocModel, PhysicalMemory
from repro.core.dram import AddressMap
from repro.core.puma import PumaAllocator

SIZES_BITS = [2_000, 8_000, 32_000, 128_000, 512_000, 2_000_000, 6_000_000]
OPS = {"zero": 1, "copy": 2, "aand": 3}


def run(emit: Callable[[str, float, float], None]) -> Dict:
    amap = AddressMap()
    model = pud.PudCostModel()
    table: Dict[str, Dict[int, float]] = {}
    for op, nops in OPS.items():
        real_op = op.replace("aand", "and")
        for bits in SIZES_BITS:
            size = max(1, bits // 8)
            t0 = time.perf_counter()
            mem = PhysicalMemory(amap, seed=0)
            pa = PumaAllocator(mem)
            pa.pim_preallocate(64)
            ops = [pa.pim_alloc(size)]
            while len(ops) < nops:
                ops.append(pa.pim_alloc_align(size, ops[0]))
            r_puma = pud.simulate_op(real_op, ops, amap, model)

            mem2 = PhysicalMemory(amap, seed=0)
            mal = MallocModel(mem2)
            r_mal = pud.simulate_op(
                real_op, [mal.alloc(size) for _ in range(nops)], amap, model
            )
            us = (time.perf_counter() - t0) * 1e6
            speedup = r_mal.t_ns / r_puma.t_ns
            emit(f"fig2/{op}/{bits}b", us, round(speedup, 3))
            table.setdefault(op, {})[bits] = speedup
    return table
