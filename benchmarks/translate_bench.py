"""Address-translation / PUD-planning microbenchmark (ISSUE 2 tentpole).

Times the vectorized fast path against faithful re-implementations of the
seed's scalar algorithms, on the workloads the issue names:

* ``decode``      — batch :meth:`AddressMap.region_subarrays` vs a scalar
                    ``region_subarray`` loop over the same region PAs
                    (target: >= 20x), under both interleave schemes.
* ``pa_of``       — bisect-over-coalesced-extents translation vs the seed's
                    linear extent scan, 8k lookups on a 512 KB malloc
                    allocation (seed: ~68 ms).
* ``plan``        — vectorized ``plan_rows`` (cold cache: the row->subarray
                    tables are rebuilt every call) vs the seed's per-row
                    scalar probe, 512 KB 3-operand op over malloc-scattered
                    allocations (seed: ~8.8 ms; target: >= 10x).
* ``execute``     — ``execute_op`` walking ``Allocation.runs()`` vs the
                    seed's byte-by-byte ``pa_of`` probing (target: >= 10x).
* ``preallocate`` — batch ``pim_preallocate(512)`` = 131,072 regions
                    decoded + pool-indexed (seed: ~1.4 s).

``run(emit)`` plugs into ``benchmarks/run.py``; ``main()`` (smoke or full)
persists ops/sec + speedups to ``BENCH_translate.json`` so future PRs have
a perf trajectory.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.core import pud
from repro.core.allocators import Allocation, MallocModel, PhysicalMemory
from repro.core.dram import (
    AddressMap,
    BANK_REGION_SCHEME,
    CACHELINE_INTERLEAVED_SCHEME,
)
from repro.core.puma import PumaAllocator

OUT_PATH = "BENCH_translate.json"


# ---------------------------------------------------------------------------
# Seed-reference implementations (the algorithms this PR replaced), kept
# here verbatim-in-spirit so the speedup baseline cannot silently drift.
# ---------------------------------------------------------------------------

def seed_pa_of(alloc: Allocation, va_off: int) -> int:
    """Seed ``Allocation.pa_of``: linear scan over the extent list."""
    for e in alloc.extents:
        if e.va_off <= va_off < e.va_off + e.nbytes:
            return e.pa + (va_off - e.va_off)
    raise ValueError(f"offset {va_off} not mapped (size={alloc.size})")


def seed_contiguous_run(alloc: Allocation, va_off: int, nbytes: int):
    """Seed ``Allocation.contiguous_run``: repeated linear scans."""
    last = alloc.extents[-1]
    if va_off + nbytes > last.va_off + last.nbytes:
        return None
    base = seed_pa_of(alloc, va_off)
    cur = va_off
    while cur < va_off + nbytes:
        for e in alloc.extents:
            if e.va_off <= cur < e.va_off + e.nbytes:
                if e.pa + (cur - e.va_off) != base + (cur - va_off):
                    return None
                cur = e.va_off + e.nbytes
                break
        else:
            return None
    return base


def seed_plan_rows(op: str, operands: Sequence[Allocation], amap: AddressMap):
    """Seed ``plan_rows``: scalar contiguous_run + region_subarray per row."""
    size = min(a.size for a in operands)
    region = amap.region_bytes
    n_full, tail = divmod(size, region)
    n_rows = n_full + (1 if tail else 0)
    in_pud: List[bool] = []
    for r in range(n_rows):
        sas = []
        for a in operands:
            pa = seed_contiguous_run(a, r * region, region)
            if pa is None or not amap.region_is_aligned(pa):
                sas.append(None)
            else:
                sas.append(amap.region_subarray(pa))
        in_pud.append(sas[0] is not None and all(s == sas[0] for s in sas))
    tail_bytes = 0 if (not tail or in_pud[-1]) else tail
    return pud.RowPlan(n_rows=n_rows, in_pud=in_pud, tail_bytes=tail_bytes)


def seed_execute_op(
    op: str, operands: Sequence[Allocation], phys: np.ndarray, amap: AddressMap
):
    """Seed ``execute_op``: grow physical runs one byte at a time."""
    plan = seed_plan_rows(op, operands, amap)
    region = amap.region_bytes
    dst, srcs = operands[-1], list(operands[:-1])

    def read(a, off, n):
        out = np.empty(n, np.uint8)
        done = 0
        while done < n:
            pa = seed_pa_of(a, off + done)
            run = 1
            while done + run < n and seed_pa_of(a, off + done + run) == pa + run:
                run += 1
            out[done : done + run] = phys[pa : pa + run]
            done += run
        return out

    def write(a, off, buf):
        done = 0
        n = len(buf)
        while done < n:
            pa = seed_pa_of(a, off + done)
            run = 1
            while done + run < n and seed_pa_of(a, off + done + run) == pa + run:
                run += 1
            phys[pa : pa + run] = buf[done : done + run]
            done += run

    for r in range(plan.n_rows):
        off = r * region
        n = region
        if not plan.in_pud[r] and r == plan.n_rows - 1 and plan.tail_bytes:
            n = plan.tail_bytes
        src_rows = [read(s, off, n) for s in srcs]
        out = np.empty(n, np.uint8)
        pud._apply_rowwise(op, out, src_rows)
        write(dst, off, out)
    return plan


# ---------------------------------------------------------------------------
# Timing harness
# ---------------------------------------------------------------------------

def _best_of(fn: Callable[[], object], repeats: int) -> float:
    """Seconds for the fastest of ``repeats`` runs (>=1 run regardless)."""
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _clear_row_caches(operands: Sequence[Allocation]) -> None:
    for a in operands:
        a._row_sa_cache.clear()


def bench(smoke: bool = False) -> Dict:
    repeats = 2 if smoke else 5
    size = 512 * 1024          # the issue's 512 KB 3-operand op
    n_decode = 20_000 if smoke else 200_000
    n_lookup = 8_000           # the issue's "8k pa_of lookups" yardstick
    results: Dict[str, Dict] = {}

    # -- decode: batch vs scalar, both schemes ------------------------------
    for name, scheme in [
        ("bank_region", BANK_REGION_SCHEME),
        ("cacheline", CACHELINE_INTERLEAVED_SCHEME),
    ]:
        amap = AddressMap(scheme=scheme)
        rb = amap.region_bytes
        rng = np.random.default_rng(0)
        pas = (
            rng.integers(0, amap.total_bytes // rb, n_decode, dtype=np.int64) * rb
        )
        pas_list = pas.tolist()

        t_scalar = _best_of(
            lambda: [amap.region_subarray(p) for p in pas_list], repeats
        )
        t_batch = _best_of(lambda: amap.region_subarrays(pas), repeats)
        results[f"decode/{name}"] = {
            "n": n_decode,
            "scalar_ops_per_s": n_decode / t_scalar,
            "batch_ops_per_s": n_decode / t_batch,
            "speedup": t_scalar / t_batch,
        }

    # -- allocation-translation workloads on malloc-scattered operands ------
    amap = AddressMap()
    mal = MallocModel(PhysicalMemory(amap, seed=3))
    operands = [mal.alloc(size) for _ in range(3)]
    a0 = operands[0]
    offs = [(i * 64) % a0.size for i in range(n_lookup)]

    t_seed = _best_of(lambda: [seed_pa_of(a0, o) for o in offs], repeats)
    t_fast = _best_of(lambda: [a0.pa_of(o) for o in offs], repeats)
    results["pa_of/malloc_512k"] = {
        "n": n_lookup,
        "scalar_ops_per_s": n_lookup / t_seed,
        "batch_ops_per_s": n_lookup / t_fast,
        "speedup": t_seed / t_fast,
    }

    t_seed = _best_of(lambda: seed_plan_rows("and", operands, amap), repeats)

    def plan_cold():
        _clear_row_caches(operands)
        return pud.plan_rows("and", operands, amap)

    t_cold = _best_of(plan_cold, repeats)
    pud.plan_rows("and", operands, amap)  # prime the row tables
    t_warm = _best_of(lambda: pud.plan_rows("and", operands, amap), repeats)
    n_rows = -(-size // amap.region_bytes)
    results["plan/malloc_512k_3op"] = {
        "n": n_rows,
        "scalar_ops_per_s": n_rows / t_seed,
        "batch_ops_per_s": n_rows / t_cold,
        "warm_ops_per_s": n_rows / t_warm,
        "speedup": t_seed / t_cold,
        "speedup_warm": t_seed / t_warm,
    }

    # -- execute: small phys memory so the array fits comfortably -----------
    from repro.core.dram import DramGeometry

    small = AddressMap(DramGeometry(subarrays_per_bank=16))  # 128 MB
    mal = MallocModel(
        PhysicalMemory(small, seed=3, occupancy=0.1, n_huge_pages=16)
    )
    ops_small = [mal.alloc(size) for _ in range(3)]
    phys = np.zeros(small.total_bytes, np.uint8)

    def exec_seed():
        _clear_row_caches(ops_small)
        return seed_execute_op("and", ops_small, phys, small)

    def exec_fast():
        _clear_row_caches(ops_small)
        return pud.execute_op("and", ops_small, phys, small)

    t_seed = _best_of(exec_seed, 1 if smoke else 2)
    t_fast = _best_of(exec_fast, repeats)
    results["execute/malloc_512k_3op"] = {
        "n": size,
        "scalar_ops_per_s": size / t_seed,
        "batch_ops_per_s": size / t_fast,
        "speedup": t_seed / t_fast,
    }

    # -- preallocate: the 131,072-region pool index -------------------------
    n_huge = 64 if smoke else 512

    def prealloc():
        mem = PhysicalMemory(amap, n_huge_pages=1024)
        pa = PumaAllocator(mem)
        return pa.pim_preallocate(n_huge)

    t = _best_of(prealloc, repeats)
    n_regions = n_huge * (2 * 1024 * 1024) // amap.region_bytes
    results[f"preallocate/{n_huge}hp"] = {
        "n": n_regions,
        "batch_ops_per_s": n_regions / t,
        "seconds": t,
    }
    return results


def run(emit: Callable[[str, float, float], None], smoke: bool = False) -> Dict:
    """benchmarks/run.py hook: emit CSV rows + persist BENCH_translate.json."""
    results = bench(smoke=smoke)
    for name, rec in results.items():
        us = 1e6 * rec["n"] / rec["batch_ops_per_s"]
        emit(f"translate/{name}", us, round(rec.get("speedup", 0.0), 2))
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    return results


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI mode")
    args = ap.parse_args()
    results = run(lambda n, us, d: print(f"{n},{us:.1f},{d}"), smoke=args.smoke)
    print(f"[translate_bench] wrote {OUT_PATH}")
    for name, rec in sorted(results.items()):
        if "speedup" in rec:
            print(f"  {name}: {rec['speedup']:.1f}x")


if __name__ == "__main__":
    main()
