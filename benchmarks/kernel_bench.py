"""Kernel micro-timings (CPU): jnp reference path wall time + kernel-vs-ref
agreement.  Interpret-mode Pallas timings are NOT hardware numbers — the TPU
performance claims live in the roofline analysis; this table tracks the
reference-path cost and correctness drift per shape."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pud_bulk import ops as pud_ops
from repro.kernels.flash_attention import ops as fl_ops


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) * 1e6 / iters


def run(emit: Callable[[str, float, float], None]) -> Dict:
    rng = np.random.default_rng(0)
    out = {}

    for rows in [1024, 8192, 65536]:
        x = jnp.asarray(rng.integers(0, 1 << 30, (rows, 128)).astype(np.int32))
        y = jnp.asarray(rng.integers(0, 1 << 30, (rows, 128)).astype(np.int32))
        us = _time(lambda a, b: pud_ops.pud_and(a, b, use_kernel=False), x, y)
        k = pud_ops.pud_and(x, y, use_kernel=True)
        r = pud_ops.pud_and(x, y, use_kernel=False)
        match = float((np.asarray(k) == np.asarray(r)).all())
        emit(f"pud_and/ref_jnp/{rows}x128", us, match)
        out[f"pud_and_{rows}"] = us

    for (B, H, S, D) in [(1, 4, 256, 64), (2, 8, 512, 64)]:
        q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
        kv = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
        us = _time(
            lambda a, b, c: fl_ops.flash_attention(a, b, c, use_kernel=False),
            q, kv, kv,
        )
        ok = fl_ops.flash_attention(q, kv, kv, use_kernel=True)
        rf = fl_ops.flash_attention(q, kv, kv, use_kernel=False)
        err = float(jnp.max(jnp.abs(ok - rf)))
        emit(f"flash/ref_jnp/B{B}H{H}S{S}D{D}", us, err)
        out[f"flash_{B}_{H}_{S}_{D}"] = err
    return out
