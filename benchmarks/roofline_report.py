"""Roofline table from experiments/*.json (computed by launch/roofline.py):
per (arch x shape), the three terms, dominant bottleneck, useful-FLOPs ratio
and roofline fraction."""
from __future__ import annotations

import json
import os
from typing import Callable, Dict


def run(emit: Callable[[str, float, float], None]) -> Dict:
    out = {}
    for tag, path in [
        ("roofline", "experiments/roofline_results.json"),
        ("roofline_final", "experiments/roofline_final_decode.json"),
    ]:
        if not os.path.exists(path):
            continue
        with open(path) as f:
            res = json.load(f)
        for key, rec in sorted(res.items()):
            if rec.get("compute_s") is None:
                continue
            step_us = max(rec["compute_s"], rec["memory_s"], rec["collective_s"]) * 1e6
            emit(f"{tag}/{rec['arch']}/{rec['shape']}", round(step_us, 1),
                 round(rec["roofline_fraction"], 4))
            out[f"{tag}|{key}"] = rec["roofline_fraction"]
    if not out:
        emit("roofline/missing", 0.0, 0.0)
    return out
